"""Measured, versioned model profiles: the Model-CI artifact schema
(DESIGN.md S9, MLModelCI analog -- continuous benchmarking as a service).

A ``ModelProfile`` is ONE measurement record per (model, cloud): the
per-request service time a placement planner needs, the prefill/decode
split when the backend exposes the two-point measurement
(``BatcherBackend.prefill_time``/``decode_time``), the memory footprint,
the cold model-load cost, and the roofline terms that explain WHERE the
service time comes from.  Profiles are content-hashed (``key``) so two
identical measurements dedupe and any change re-versions the artifact.

``ProfileStore`` keeps profiles inside a pipelines ``ArtifactCache`` --
the same content-addressed, residency-aware store the orchestrator's step
artifacts live in -- so profile artifacts obey the exact cloud-residency
and egress-pricing rules every other artifact does (``pull`` prices a
cross-cloud move with ``artifacts.best_transfer`` and commits the new
residency).  ``demand()`` is the profile -> ``ModelDemand`` bridge: every
demand number the placement planner sees becomes a measured quantity.

Measurement split (DESIGN.md S1): ``measure()`` wall-clocks a real
backend on this host; ``roofline_fields()`` derives an analytic profile
from an ArchConfig + HardwareSpec with no compilation (the registry-model
path: ``model_flops`` and the weight-streaming bytes bound are closed
forms of the config).  Cloud-specific terms (``load_s``) are CloudProfile
constants stamped at commit time -- the host measurement is
cloud-independent, the constants are not.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Any, Optional

from ..clouds.profiles import PROFILES, CloudProfile, HardwareSpec, TPU_V5E
from ..launch.roofline import model_flops, roofline
from ..pipelines.artifacts import (ArtifactCache, best_transfer,
                                   payload_bytes)
from ..serving.gateway.placement import ModelDemand


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """One measured profile artifact: (model, cloud) -> the numbers
    placement and drift detection consume.  ``service_time_s`` is the
    PER-REQUEST service time at ``max_batch`` (the planner's unit);
    ``prefill_s``/``decode_s`` split it when the backend is
    disaggregated.  JSON-able end to end (``value_cacheable``), so the
    artifact persists through the shared cache machinery."""
    model: str
    cloud: str
    service_time_s: float
    max_batch: int = 1
    prefill_s: Optional[float] = None
    decode_s: Optional[float] = None
    memory_bytes: int = 0
    load_s: float = 0.0                  # cold model load on this cloud
    roofline: Optional[dict] = None      # RooflineTerms.as_dict(), if known
    source: str = "measured"             # measured | roofline

    def __post_init__(self):
        if self.service_time_s <= 0 or not math.isfinite(self.service_time_s):
            raise ValueError(f"{self.model}: service_time_s must be a "
                             f"positive finite measurement, "
                             f"got {self.service_time_s}")
        if (self.prefill_s is None) != (self.decode_s is None):
            raise ValueError(f"{self.model}: prefill_s and decode_s come "
                             "from one two-point measurement; set both "
                             "or neither")

    @property
    def effective_service_s(self) -> float:
        if self.prefill_s is not None and self.decode_s is not None:
            return self.prefill_s + self.decode_s
        return self.service_time_s

    @property
    def key(self) -> str:
        """Content-hash version: any field change re-keys the artifact
        (the ``step_cache_key`` discipline, applied to measurements)."""
        blob = json.dumps(self.to_dict(), sort_keys=True)
        return "profile_" + hashlib.sha256(blob.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # canonical float rounding so a re-measurement that agrees to
        # float noise hashes identically only when truly identical, but
        # the JSON never carries repr jitter
        for k in ("service_time_s", "prefill_s", "decode_s", "load_s"):
            if d[k] is not None:
                d[k] = float(d[k])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ModelProfile":
        return cls(**{f.name: d.get(f.name, f.default)
                      for f in dataclasses.fields(cls)})

    def demand(self, *, rate: Optional[float] = None,
               load_erlangs: Optional[float] = None) -> ModelDemand:
        """The profile -> planner bridge for ONE cloud's numbers."""
        if (rate is None) == (load_erlangs is None):
            raise ValueError("set exactly one of rate / load_erlangs")
        if rate is None:
            rate = load_erlangs / self.effective_service_s
        return ModelDemand(self.model, rate, self.service_time_s,
                           prefill_s=self.prefill_s,
                           decode_s=self.decode_s)


# -- measurement --------------------------------------------------------------

def measure(backend, *, max_batch: int = 32,
            weights: Any = None) -> dict:
    """Measure a live backend into the raw profile FIELD dict (JSON-able,
    cloud-agnostic -- a profile step's fn returns exactly this, so the
    measurement caches across recurring runs).  Uses the backend's own
    measured cost models: ``service_time(max_batch)`` for the blended
    per-request time, plus ``prefill_time``/``decode_time`` when the
    backend carries the two-point disaggregated measurement
    (``BatcherBackend``)."""
    svc = backend.service_time(max_batch) / max_batch
    fields: dict = {"service_time_s": float(svc),
                    "max_batch": int(max_batch),
                    "source": "measured"}
    if hasattr(backend, "prefill_time") and hasattr(backend, "decode_time"):
        fields["prefill_s"] = float(backend.prefill_time())
        fields["decode_s"] = float(backend.decode_time())
    if weights is not None:
        fields["memory_bytes"] = payload_bytes(weights)
    return fields


def roofline_fields(cfg, *, shape_kind: str = "decode", batch: int = 1,
                    seq: int = 1, gen_tokens: int = 32, chips: int = 1,
                    hw: HardwareSpec = TPU_V5E) -> dict:
    """Analytic profile fields for a registry ArchConfig, no compilation:
    compute from ``model_flops`` (closed form of the config), memory from
    streaming the active weights once per token (the decode bandwidth
    bound), zero collective bytes per chip at chips=1.  A decode-shaped
    request costs ``gen_tokens`` roofline-bound steps.  This is the
    zero-hand-tuned-numbers path: every term derives from the config and
    the HardwareSpec constants."""
    per_tok_flops = model_flops(cfg, shape_kind, batch, seq) / chips
    weight_bytes = 2.0 * cfg.approx_active_params() / chips   # bf16 stream
    terms = roofline(per_tok_flops, weight_bytes, 0.0, chips, hw=hw)
    svc = terms.total_s * gen_tokens / max(batch, 1)
    return {"service_time_s": float(svc),
            "max_batch": int(batch),
            "memory_bytes": int(2 * cfg.approx_active_params()),
            "roofline": terms.as_dict(),
            "source": "roofline"}


def finalize(fields: dict, model: str, cloud: CloudProfile) -> ModelProfile:
    """Stamp cloud-agnostic measured fields into the (model, cloud)
    artifact: the cold-load cost is the ONE cloud-specific constant
    (CloudProfile.model_load_s), applied at commit time."""
    return ModelProfile(model=model, cloud=cloud.name,
                        load_s=float(cloud.model_load_s), **fields)


# -- the store ----------------------------------------------------------------

class ProfileStore:
    """Content-addressed profile artifacts over a pipelines ArtifactCache.

    ``put`` writes the profile's dict under its content-hash key with the
    producing cloud as residency (the exact ``ArtifactCache.put`` rules,
    so an ArtifactStore-backed cache persists profiles across processes);
    ``latest`` tracks the newest key per (model, cloud) so re-profiles
    supersede without destroying history.  ``pull`` prices moving a
    profile to a consuming cloud through ``best_transfer`` -- the one
    shared egress rule -- and commits the new residency.
    """

    def __init__(self, cache: Optional[ArtifactCache] = None):
        self.cache = cache if cache is not None else ArtifactCache()
        self.latest: dict[tuple, str] = {}   # (model, cloud) -> cache key

    def put(self, profile: ModelProfile):
        key = profile.key
        entry = self.cache.entries.get(key)
        if entry is None:
            entry = self.cache.put(key, profile.to_dict(),
                                   f"profile:{profile.model}", profile.cloud)
        else:                            # identical re-measurement: dedupe,
            entry.clouds.add(profile.cloud)   # extend residency
        self.latest[(profile.model, profile.cloud)] = key
        return entry

    def get(self, model: str, cloud: str) -> Optional[ModelProfile]:
        key = self.latest.get((model, cloud))
        if key is None:
            return None
        entry = self.cache.get(key)
        if entry is None:
            return None
        return ModelProfile.from_dict(entry.value)

    def clouds(self, model: str) -> list:
        return sorted(c for m, c in self.latest if m == model)

    def models(self) -> list:
        return sorted({m for m, _ in self.latest})

    def pull(self, model: str, cloud: str, dst: CloudProfile,
             profiles: Optional[dict] = None):
        """Make (model, cloud)'s artifact resident on ``dst``; returns
        (entry, transfer_s, egress_usd) -- (entry, 0, 0) when dst already
        holds a copy.  Pricing and source choice are ``best_transfer``'s,
        residency commit is ``commit_transfer``'s: profiles are ordinary
        artifacts under the ordinary rules."""
        key = self.latest.get((model, cloud))
        entry = self.cache.get(key) if key else None
        if entry is None:
            raise KeyError(f"no profile for ({model!r}, {cloud!r})")
        move = best_transfer(entry.clouds, entry.nbytes, dst,
                             profiles or PROFILES)
        if move is None:
            return entry, 0.0, 0.0
        _src, t_s, usd = move
        self.cache.commit_transfer(entry, dst.name)
        return entry, t_s, usd

    def worst(self, model: str, clouds: Optional[list] = None) -> ModelProfile:
        """The committed profile with the LARGEST effective service time
        among ``clouds`` (names; default: every profiled cloud) -- the
        conservative pick a split placement sizes against."""
        names = clouds if clouds is not None else self.clouds(model)
        profs = [p for p in (self.get(model, c) for c in names)
                 if p is not None]
        if not profs:
            raise KeyError(f"no profile artifacts for {model!r} on "
                           f"{list(names)!r}: run the profiling DAG first")
        return max(profs, key=lambda p: p.effective_service_s)

    def demand(self, model: str, *, rate: Optional[float] = None,
               load_erlangs: Optional[float] = None,
               clouds: Optional[list] = None) -> ModelDemand:
        """Build the planner's ModelDemand from committed profiles.  With
        several per-cloud profiles the WORST (largest) service time wins:
        a split placement must not under-provision its slowest share.
        ``clouds`` restricts to the placement's candidate clouds (cloud
        names); profiles must exist for at least one."""
        return self.worst(model, clouds).demand(rate=rate,
                                                load_erlangs=load_erlangs)
