"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch,
expert-parallel over the mesh "model" axis.

Dispatch is GShard-style *grouped*: each batch row routes its own tokens
independently (vmap over batch), so routing never crosses the data-parallel
axis -- the only cross-device traffic is the expert-parallel all-to-all that
XLA SPMD inserts around the (E, C, D) expert buffers (experts sharded over
"model").  That collective is the MoE term the roofline watches.

Capacity per group: C = ceil(cf * S * top_k / E); overflowing tokens are
dropped (contribute zero), standard Switch/GShard semantics.  The auxiliary
load-balance loss (Switch eq. 4 generalised to top-k) is returned to the
train loss.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import modules as nn
from .sharding import constrain

Params = Any


def _epad(cfg: ArchConfig) -> int:
    """Stored expert count: padded (dead) experts let E divide the mesh."""
    return max(cfg.expert_pad_to, cfg.n_experts)


def moe_init(key, cfg: ArchConfig, dtype) -> Params:
    d, e, f = cfg.d_model, _epad(cfg), cfg.moe_d_ff
    ks = nn.split_keys(key, 7)
    p = {
        "router": nn.dense_init(ks[0], (d, cfg.n_experts), fan_in=d,
                                dtype=jnp.float32),
        "experts_gate": nn.dense_init(ks[1], (e, d, f), fan_in=d, dtype=dtype),
        "experts_up": nn.dense_init(ks[2], (e, d, f), fan_in=d, dtype=dtype),
        "experts_down": nn.dense_init(ks[3], (e, f, d), fan_in=f, dtype=dtype),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_gate"] = nn.dense_init(ks[4], (d, fs), fan_in=d, dtype=dtype)
        p["shared_up"] = nn.dense_init(ks[5], (d, fs), fan_in=d, dtype=dtype)
        p["shared_down"] = nn.dense_init(ks[6], (fs, d), fan_in=fs, dtype=dtype)
    return p


def capacity(cfg: ArchConfig, group_tokens: int) -> int:
    c = math.ceil(cfg.capacity_factor * group_tokens * cfg.top_k / cfg.n_experts)
    return max(c, cfg.top_k)


def _route_group(x: jax.Array, router_logits: jax.Array, cfg: ArchConfig, cap: int):
    """One group's dispatch. x: (S,D), router_logits: (S, n_experts) fp32.

    Returns (dispatch buffers, routing state, router probs).  Dead padded
    experts (expert_pad_to) get no router logits, so top_k never picks
    them -- they only exist so the buffer's E dim divides the mesh."""
    s, d = x.shape
    e, k = _epad(cfg), cfg.top_k
    probs = jax.nn.softmax(router_logits, axis=-1)                 # (S,E)
    gates, ids = jax.lax.top_k(probs, k)                           # (S,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_ids = ids.reshape(-1)                                     # (S*k,)
    order = jnp.argsort(flat_ids)                                  # stable
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=e)                      # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_expert = jnp.arange(s * k) - starts[sorted_ids]
    keep = pos_in_expert < cap
    slot = jnp.where(keep, sorted_ids * cap + pos_in_expert, e * cap)  # overflow row

    tok_idx = order // k                                           # source token
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(
        jnp.where(keep[:, None], x[tok_idx], 0))
    buf = buf[:-1].reshape(e, cap, d)
    return buf, (order, slot, keep, tok_idx, gates), probs


def _combine_group(buf_out: jax.Array, route, s: int, k: int, dtype):
    order, slot, keep, tok_idx, gates = route
    e, cap, d = buf_out.shape
    flat = buf_out.reshape(e * cap, d)
    picked = jnp.where(keep[:, None], flat[jnp.minimum(slot, e * cap - 1)], 0)
    # scatter back to (S*k) assignment order, then weight by gates and sum k
    unsorted = jnp.zeros((s * k, d), dtype).at[order].set(picked.astype(dtype))
    return (unsorted.reshape(s, k, d) * gates[..., None].astype(dtype)).sum(axis=1)


def moe_forward(p: Params, x: jax.Array, cfg: ArchConfig, *,
                no_drop: bool = False):
    """x: (B,S,D) -> (y (B,S,D), aux_loss scalar).

    no_drop: capacity = S*k so no token ever overflows -- the chunked
    prefill path uses this to stay equivalent to one-token decode, where
    each token is routed alone and capacity never binds."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = s * k if no_drop else capacity(cfg, s)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))

    bufs, routes, probs = jax.vmap(
        lambda xg, lg: _route_group(xg, lg, cfg, cap))(x, logits)
    # (B,E,C,D): expert-parallel when E divides the model axis; otherwise
    # the trailing "model" fallback shards D so the capacity buffers (the
    # dominant MoE memory term, cf*k times the token count) never sit
    # replicated on every chip (EXPERIMENTS.md §Perf iteration A2)
    bufs = constrain(bufs, "batch", "expert", None, "model")

    # expert compute (batched over B groups; experts sharded over model axis)
    h_gate = jnp.einsum("becd,edf->becf", bufs, p["experts_gate"])
    h_up = jnp.einsum("becd,edf->becf", bufs, p["experts_up"])
    h = nn.swiglu(h_up, h_gate)
    h = constrain(h, "batch", "expert", None, "model")
    out_buf = jnp.einsum("becf,efd->becd", h, p["experts_down"])
    out_buf = constrain(out_buf, "batch", "expert", None, "model")

    y = jax.vmap(lambda bo, r: _combine_group(bo, r, s, k, x.dtype))(out_buf, routes)

    # Switch-style load-balance aux loss, averaged over groups
    me = probs.mean(axis=1)                                        # (B,E)
    top1 = jnp.argmax(logits, axis=-1)
    ce = jax.vmap(lambda t: jnp.bincount(t, length=e) / s)(top1)   # (B,E)
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))

    if cfg.n_shared_experts:
        sh = nn.swiglu(jnp.einsum("bsd,df->bsf", x, p["shared_up"]),
                       jnp.einsum("bsd,df->bsf", x, p["shared_gate"]))
        y = y + jnp.einsum("bsf,fd->bsd", sh, p["shared_down"])
    return y, aux.astype(jnp.float32)
