"""Attention variants: GQA (+RoPE / M-RoPE / sliding window), MLA (deepseek),
cross-attention (whisper).  Pure functions over param dicts.

Decode ("serve_step") semantics: ONE new token per sequence against a KV
cache of length cfg.max_decode_len.  Sliding-window ("local") layers use a
ring-buffer cache of size min(window, max_decode_len) -- correct because
post-RoPE attention is permutation-invariant over keys, so ring order does
not matter once positions are baked in at write time.

MLA keeps the *compressed* cache (c_kv, k_rope) and decodes in the absorbed
form (q folded through w_uk / output through w_uv) -- the memory win the
paper's MLA citation exists for.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels import ops
from . import modules as nn
from .sharding import constrain

Params = Any


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, head_dim // 2, dtype=jnp.float32) / (head_dim // 2))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B,S,H,D); positions: (B,S) -> rotated x (half-split convention)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                   # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (B,S,D/2)
    cos, sin = jnp.cos(angles)[:, :, None, :], jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Qwen2-VL M-RoPE: positions (B,3,S) = (t,h,w) streams; the rotary
    frequency dims are split into 3 sections, one per stream."""
    d = x.shape[-1]
    half = d // 2
    s1 = half - 2 * (half // 3)
    sections = [s1, half // 3, half // 3]
    freqs = rope_freqs(d, theta)
    pos_f = positions.astype(jnp.float32)                          # (B,3,S)
    parts, start = [], 0
    for i, sec in enumerate(sections):
        ang = pos_f[:, i, :, None] * freqs[start:start + sec]      # (B,S,sec)
        parts.append(ang)
        start += sec
    angles = jnp.concatenate(parts, axis=-1)                       # (B,S,D/2)
    cos, sin = jnp.cos(angles)[:, :, None, :], jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _rope(x, positions, cfg: ArchConfig):
    if not cfg.use_rope:
        return x
    if cfg.use_mrope:
        return apply_mrope(x, positions, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)


def _tpos(positions, cfg: ArchConfig):
    """Temporal (1D) position stream -- for cache indexing under M-RoPE."""
    return positions[:, 0] if cfg.use_mrope else positions


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def gqa_init(key, cfg: ArchConfig, dtype) -> Params:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = nn.split_keys(key, 4)
    return {
        "wq": nn.dense_init(k1, (d, hq, hd), fan_in=d, dtype=dtype),
        "wk": nn.dense_init(k2, (d, hkv, hd), fan_in=d, dtype=dtype),
        "wv": nn.dense_init(k3, (d, hkv, hd), fan_in=d, dtype=dtype),
        "wo": nn.dense_init(k4, (hq, hd, d), fan_in=hq * hd, dtype=dtype),
    }


def gqa_forward(p: Params, x: jax.Array, positions: jax.Array, cfg: ArchConfig,
                *, window: int = 0, causal: bool = True,
                return_kv: bool = False):
    """Full-sequence attention (train / prefill). x: (B,S,D)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = constrain(_rope(q, positions, cfg), "batch", None, "model")
    k = constrain(_rope(k, positions, cfg), "batch", None, "model")
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            use_kernel=cfg.use_kernels,
                            chunked=cfg.fused_attention,
                            chunk_k=cfg.attn_chunk,
                            unroll=cfg.scan_unroll if cfg.chunk_unroll is None
                            else cfg.chunk_unroll)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    out = constrain(out, "batch", None, None)
    if return_kv:
        return out, (k, v)
    return out


def gqa_decode(p: Params, x: jax.Array, cache: dict, positions: jax.Array,
               cfg: ArchConfig, *, window: int = 0):
    """One-token decode. x: (B,1,D); cache {k,v:(B,S,Hkv,hd)}; positions (B,)
    or (B,3) absolute positions of the new token.  Returns (out, new_cache)."""
    b = x.shape[0]
    # positions for rope helpers expect (B,S) or (B,3,S)
    pos_seq = positions[:, None] if not cfg.use_mrope else positions[:, :, None]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = _rope(q, pos_seq, cfg)[:, 0]                               # (B,Hq,hd)
    k = _rope(k, pos_seq, cfg)[:, 0]                               # (B,Hkv,hd)
    v = v[:, 0]
    tpos = _tpos(pos_seq, cfg)[:, 0]                               # (B,) int
    cache_size = cache["k"].shape[1]
    if window > 0:                      # ring-buffer cache for local layers
        size = min(window, cache_size)
        slot = tpos % size
        eff_len = jnp.minimum(tpos + 1, size)
    else:
        slot = tpos
        eff_len = tpos + 1
    bidx = jnp.arange(b)
    k_cache = cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype))
    o = ops.decode_attention(q, k_cache, v_cache, eff_len.astype(jnp.int32),
                             use_kernel=cfg.use_kernels)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
    return out, {"k": k_cache, "v": v_cache}


def gqa_prefill(p: Params, x: jax.Array, cache: dict, positions: jax.Array,
                cfg: ArchConfig, *, window: int = 0):
    """Chunked prefill: C prompt tokens at once against the decode cache.

    x: (B,C,D); positions: (B,C) (or (B,3,C) M-RoPE) absolute, contiguous
    ascending; cache {k,v:(B,S,Hkv,hd)}.  Writes the chunk's K/V rows into
    the cache and attends every query with the same masked softmax the
    one-token decode path (`gqa_decode` -> decode_attention_ref) uses, so a
    P-token prompt costs O(P/C) calls instead of P decode steps while
    producing decode-identical logits: rows past a query's position differ
    (written here, zero in decode) but are masked to the same exact NEG_INF
    before the softmax.  Returns (out (B,C,D), new_cache)."""
    b, c, _ = x.shape
    hq = cfg.n_heads
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = _rope(q, positions, cfg)                                   # (B,C,Hq,hd)
    k = _rope(k, positions, cfg)                                   # (B,C,Hkv,hd)
    tpos = _tpos(positions, cfg)                                   # (B,C) int
    cache_size = cache["k"].shape[1]
    scale = q.shape[-1] ** -0.5
    group = max(hq // k.shape[2], 1)
    k_cd, v_cd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)

    if window > 0:
        # Ring buffer: reconstruct, per query, the ring exactly as it stood
        # at that query's decode step.  Slot s at time t holds position
        # cand = t - ((t - s) % size); if cand falls inside this chunk the
        # key is a chunk row, otherwise it is the pre-chunk ring content.
        size = min(window, cache_size)
        slots = jnp.arange(size)
        start = tpos[:, :1]                                        # chunk offset
        cand = tpos[:, :, None] - ((tpos[:, :, None] - slots[None, None, :]) % size)
        from_chunk = cand >= start[:, :, None]                     # (B,C,size)
        idx = jnp.clip(cand - start[:, :, None], 0, c - 1)
        b3 = jnp.arange(b)[:, None, None]
        sel = from_chunk[..., None, None]
        keys = jnp.where(sel, k_cd[b3, idx], cache["k"][:, None])  # (B,C,size,Hkv,hd)
        vals = jnp.where(sel, v_cd[b3, idx], cache["v"][:, None])
        keys = jnp.repeat(keys, group, axis=3) if group > 1 else keys
        vals = jnp.repeat(vals, group, axis=3) if group > 1 else vals
        logits = jnp.einsum("bqhd,bqkhd->bqhk", q.astype(keys.dtype), keys,
                            preferred_element_type=jnp.float32) * scale
        eff_len = jnp.minimum(tpos + 1, size)                      # (B,C)
        valid = slots[None, None, :] < eff_len[:, :, None]
        logits = jnp.where(valid[:, :, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bqhk,bqkhd->bqhd", probs.astype(vals.dtype), vals,
                       preferred_element_type=jnp.float32).astype(q.dtype)
        # final ring state: per slot, the last chunk position that maps there
        # (deterministic gather -- scatter with duplicate ring indices is not)
        last = tpos[:, -1:]
        cand_f = last - ((last - slots[None, :]) % size)           # (B,size)
        sel_f = (cand_f >= start)[..., None, None]
        idx_f = jnp.clip(cand_f - start, 0, c - 1)
        b2 = jnp.arange(b)[:, None]
        k_cache = jnp.where(sel_f, k_cd[b2, idx_f], cache["k"])
        v_cache = jnp.where(sel_f, v_cd[b2, idx_f], cache["v"])
    else:
        b2 = jnp.arange(b)[:, None]
        k_cache = cache["k"].at[b2, tpos].set(k_cd)
        v_cache = cache["v"].at[b2, tpos].set(v_cd)
        keys = jnp.repeat(k_cache, group, axis=2) if group > 1 else k_cache
        vals = jnp.repeat(v_cache, group, axis=2) if group > 1 else v_cache
        logits = jnp.einsum("bqhd,bkhd->bqhk", q.astype(keys.dtype), keys,
                            preferred_element_type=jnp.float32) * scale
        valid = jnp.arange(cache_size)[None, None, :] < (tpos[:, :, None] + 1)
        logits = jnp.where(valid[:, :, None, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bqhk,bkhd->bqhd", probs.astype(vals.dtype), vals,
                       preferred_element_type=jnp.float32).astype(q.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": k_cache, "v": v_cache}


def gqa_decode_stacked(p: Params, x: jax.Array, stacked: dict, g: int,
                       positions: jax.Array, cfg: ArchConfig, *, window: int = 0):
    """One-token decode writing DIRECTLY into the layer-stacked cache
    (G,B,S,Hkv,hd) via dynamic-update-slice -- no per-layer slice copy and
    no post-scan restack (EXPERIMENTS.md §Perf C3: the functional per-layer
    update cost two full cache copies per step)."""
    b = x.shape[0]
    pos_seq = positions[:, None] if not cfg.use_mrope else positions[:, :, None]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = _rope(q, pos_seq, cfg)[:, 0]
    k = _rope(k, pos_seq, cfg)[:, 0]
    v = v[:, 0]
    tpos = _tpos(pos_seq, cfg)[:, 0]
    cache_size = stacked["k"].shape[2]
    if window > 0:
        size = min(window, cache_size)
        slot = tpos % size
        eff_len = jnp.minimum(tpos + 1, size)
    else:
        slot = tpos
        eff_len = tpos + 1
    bidx = jnp.arange(b)
    k_st = stacked["k"].at[g, bidx, slot].set(k.astype(stacked["k"].dtype))
    v_st = stacked["v"].at[g, bidx, slot].set(v.astype(stacked["v"].dtype))
    o = ops.decode_attention(q, k_st[g], v_st[g], eff_len.astype(jnp.int32),
                             use_kernel=cfg.use_kernels)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
    new = dict(stacked, k=k_st, v=v_st)
    return out, new


def mla_decode_stacked(p: Params, x: jax.Array, stacked: dict, g: int,
                       positions: jax.Array, cfg: ArchConfig):
    """Absorbed-form MLA decode over the stacked compressed cache (§Perf C3)."""
    b = x.shape[0]
    r = cfg.kv_lora_rank
    pos_seq = positions[:, None]
    c = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_new, krope_new = c[..., :r][:, 0], c[..., r:]
    krope_new = apply_rope(krope_new[:, :, None, :], pos_seq, cfg.rope_theta)[:, 0, 0]
    bidx = jnp.arange(b)
    ckv_st = stacked["c_kv"].at[g, bidx, positions].set(
        c_new.astype(stacked["c_kv"].dtype))
    krope_st = stacked["k_rope"].at[g, bidx, positions].set(
        krope_new.astype(stacked["k_rope"].dtype))
    c_kv, k_rope = ckv_st[g], krope_st[g]

    q_nope = jnp.einsum("bsd,dhk->bshk", x, p["w_uq"])[:, 0]
    q_rope = apply_rope(jnp.einsum("bsd,dhk->bshk", x, p["w_qr"]), pos_seq,
                        cfg.rope_theta)[:, 0]
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope, p["w_uk"])
    logits = (jnp.einsum("bhr,bsr->bhs", q_abs.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhk,bsk->bhs", q_rope.astype(k_rope.dtype), k_rope,
                           preferred_element_type=jnp.float32)) * _mla_scale(cfg)
    valid = jnp.arange(c_kv.shape[1])[None, :] <= positions[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", probs.astype(c_kv.dtype), c_kv,
                     preferred_element_type=jnp.float32)
    o = jnp.einsum("bhr,rhk->bhk", o_c.astype(x.dtype), p["w_uv"])
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
    return out, dict(stacked, c_kv=ckv_st, k_rope=krope_st)


def gqa_cache_shape(cfg: ArchConfig, batch: int, length: int, window: int = 0):
    size = min(window, length) if window > 0 else length
    kv = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": kv, "v": kv}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------
def cross_init(key, cfg: ArchConfig, dtype) -> Params:
    return gqa_init(key, cfg, dtype)


def cross_forward(p: Params, x: jax.Array, enc_kv: tuple, cfg: ArchConfig):
    """x: (B,S,D); enc_kv = (k,v) precomputed from encoder output."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = enc_kv
    o = ops.flash_attention(q, k, v, causal=False, use_kernel=cfg.use_kernels)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cross_kv(p: Params, enc_out: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


def cross_decode(p: Params, x: jax.Array, enc_kv: tuple, cfg: ArchConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0]              # (B,H,hd)
    k, v = enc_kv
    lens = jnp.full((x.shape[0],), k.shape[1], jnp.int32)
    o = ops.decode_attention(q, k, v, lens, use_kernel=cfg.use_kernels)
    return jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank compressed KV + decoupled RoPE
# ---------------------------------------------------------------------------
def mla_init(key, cfg: ArchConfig, dtype) -> Params:
    d, hq = cfg.d_model, cfg.n_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    ks = nn.split_keys(key, 6)
    return {
        "w_uq": nn.dense_init(ks[0], (d, hq, dn), fan_in=d, dtype=dtype),
        "w_qr": nn.dense_init(ks[1], (d, hq, dr), fan_in=d, dtype=dtype),
        "w_dkv": nn.dense_init(ks[2], (d, r + dr), fan_in=d, dtype=dtype),
        "w_uk": nn.dense_init(ks[3], (r, hq, dn), fan_in=r, dtype=dtype),
        "w_uv": nn.dense_init(ks[4], (r, hq, dv), fan_in=r, dtype=dtype),
        "wo": nn.dense_init(ks[5], (hq, dv, d), fan_in=hq * dv, dtype=dtype),
    }


def _mla_scale(cfg: ArchConfig) -> float:
    return (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5


def mla_forward(p: Params, x: jax.Array, positions: jax.Array, cfg: ArchConfig,
                *, return_cache: bool = False):
    """Prefill/train: decompress to MHA and run flash attention."""
    r = cfg.kv_lora_rank
    c = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])                   # (B,S,r+dr)
    c_kv, k_rope = c[..., :r], c[..., r:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,dr)
    q_nope = jnp.einsum("bsd,dhk->bshk", x, p["w_uq"])
    q_rope = apply_rope(jnp.einsum("bsd,dhk->bshk", x, p["w_qr"]), positions,
                        cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    hq = cfg.n_heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, q_rope.shape)], axis=-1)
    q = constrain(q, "batch", None, "model")
    # v head dim differs from qk dim -> pad v for the fused kernel path, or
    # use the reference path which supports it natively.
    dqk, dv = q.shape[-1], v.shape[-1]
    if cfg.use_kernels and dv != dqk:
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dqk - dv)))
        o = ops.flash_attention(q, k, v_p, causal=True, scale=_mla_scale(cfg),
                                use_kernel=True)[..., :dv]
    else:
        o = ops.flash_attention(q, k, v, causal=True, use_kernel=False,
                                chunked=cfg.fused_attention,
                                chunk_k=cfg.attn_chunk,
                                unroll=cfg.scan_unroll if cfg.chunk_unroll is None
                                else cfg.chunk_unroll)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if return_cache:
        return out, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
    return out


def mla_decode(p: Params, x: jax.Array, cache: dict, positions: jax.Array,
               cfg: ArchConfig):
    """Absorbed-form decode over the compressed cache.

    cache: {c_kv: (B,S,r), k_rope: (B,S,dr)}; positions: (B,) absolute."""
    b = x.shape[0]
    r = cfg.kv_lora_rank
    pos_seq = positions[:, None]
    c = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_new, krope_new = c[..., :r][:, 0], c[..., r:]
    krope_new = apply_rope(krope_new[:, :, None, :], pos_seq, cfg.rope_theta)[:, 0, 0]
    bidx = jnp.arange(b)
    c_kv = cache["c_kv"].at[bidx, positions].set(c_new.astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[bidx, positions].set(krope_new.astype(cache["k_rope"].dtype))

    q_nope = jnp.einsum("bsd,dhk->bshk", x, p["w_uq"])[:, 0]       # (B,H,dn)
    q_rope = apply_rope(jnp.einsum("bsd,dhk->bshk", x, p["w_qr"]), pos_seq,
                        cfg.rope_theta)[:, 0]                      # (B,H,dr)
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope, p["w_uk"])          # absorbed q
    # scores over the compressed cache: native-dtype dots + f32 accumulation
    # (an .astype(f32) here would materialise an f32 copy of the WHOLE cache
    # per layer -- the dominant byte term of the decode baseline, §Perf C1)
    logits = (jnp.einsum("bhr,bsr->bhs", q_abs.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhk,bsk->bhs", q_rope.astype(k_rope.dtype), k_rope,
                           preferred_element_type=jnp.float32)) * _mla_scale(cfg)
    valid = jnp.arange(c_kv.shape[1])[None, :] <= positions[:, None]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", probs.astype(c_kv.dtype), c_kv,
                     preferred_element_type=jnp.float32)               # (B,H,r)
    o = jnp.einsum("bhr,rhk->bhk", o_c.astype(x.dtype), p["w_uv"])     # (B,H,dv)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None, :]
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_prefill(p: Params, x: jax.Array, cache: dict, positions: jax.Array,
                cfg: ArchConfig):
    """Chunked prefill in the absorbed form over the compressed cache.

    x: (B,C,D); positions: (B,C) absolute, contiguous ascending.  Decode
    twin of `mla_decode`: writes the chunk's compressed rows, then runs the
    same absorbed-einsum masked softmax for all C queries at once."""
    b, c = x.shape[:2]
    r = cfg.kv_lora_rank
    cc = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])                  # (B,C,r+dr)
    c_new, krope_new = cc[..., :r], cc[..., r:]
    krope_new = apply_rope(krope_new[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    b2 = jnp.arange(b)[:, None]
    c_kv = cache["c_kv"].at[b2, positions].set(c_new.astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[b2, positions].set(krope_new.astype(cache["k_rope"].dtype))

    q_nope = jnp.einsum("bsd,dhk->bshk", x, p["w_uq"])             # (B,C,H,dn)
    q_rope = apply_rope(jnp.einsum("bsd,dhk->bshk", x, p["w_qr"]), positions,
                        cfg.rope_theta)                            # (B,C,H,dr)
    q_abs = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["w_uk"])
    logits = (jnp.einsum("bqhr,bsr->bqhs", q_abs.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhk,bsk->bqhs", q_rope.astype(k_rope.dtype), k_rope,
                           preferred_element_type=jnp.float32)) * _mla_scale(cfg)
    valid = jnp.arange(c_kv.shape[1])[None, None, :] <= positions[:, :, None]
    logits = jnp.where(valid[:, :, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_c = jnp.einsum("bqhs,bsr->bqhr", probs.astype(c_kv.dtype), c_kv,
                     preferred_element_type=jnp.float32)
    o = jnp.einsum("bqhr,rhk->bqhk", o_c.astype(x.dtype), p["w_uv"])
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_cache_shape(cfg: ArchConfig, batch: int, length: int):
    return {"c_kv": (batch, length, cfg.kv_lora_rank),
            "k_rope": (batch, length, cfg.qk_rope_dim)}
