"""LeNet-style MNIST classifier in pure JAX (the paper's Katib model:
"docker.io/liuhougangxa/tf-estimator-mnist uses LeNet").
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import modules as nn

Params = Any


def init_params(key, *, width: int = 16) -> Params:
    ks = nn.split_keys(key, 4)
    return {
        "conv1": {"w": nn.dense_init(ks[0], (5, 5, 1, width), fan_in=25),
                  "b": jnp.zeros((width,))},
        "conv2": {"w": nn.dense_init(ks[1], (5, 5, width, width * 2), fan_in=25 * width),
                  "b": jnp.zeros((width * 2,))},
        "fc1": {"w": nn.dense_init(ks[2], (7 * 7 * width * 2, 128)),
                "b": jnp.zeros((128,))},
        "fc2": {"w": nn.dense_init(ks[3], (128, 10)), "b": jnp.zeros((10,))},
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def apply(params: Params, images: jax.Array) -> jax.Array:
    """images: (B,28,28,1) -> logits (B,10)."""
    x = jax.nn.relu(_conv(images, params["conv1"]["w"], params["conv1"]["b"]))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = jax.nn.relu(_conv(x, params["conv2"]["w"], params["conv2"]["b"]))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def loss_fn(params: Params, batch: dict):
    logits = apply(params, batch["image"])
    labels = batch["label"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "accuracy": acc}
