"""Full language models (+ whisper enc-dec, qwen2-vl vision merge).

Three pure entry points used by steps.py / launch:
  init_params(key, cfg)                     -> params pytree (eval_shape-able)
  forward(params, cfg, batch, collect_cache)-> (logits, aux, cache|None)
  decode_step(params, cfg, token, positions, cache) -> (logits, new_cache)
plus init_cache(cfg, batch) building zeroed decode caches.

`batch` keys: tokens (B,S) int32; optional vision_embeds (B,n_vis,D),
mrope_positions (B,3,S), frames (B,enc_len,D) for audio.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn
from . import blocks
from . import modules as nn
from .sharding import constrain

Params = Any


def sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """positions: (B,S) -> (B,S,D) classic transformer sinusoid."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ArchConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    plan = blocks.build_plan(cfg)
    keys = nn.split_keys(key, 6 + len(plan))
    p: dict = {
        "embed": nn.dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                               fan_in=cfg.d_model, dtype=dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = nn.dense_init(keys[1], (cfg.d_model, cfg.vocab_size),
                                     fan_in=cfg.d_model, dtype=dtype)
    cross = cfg.family == "audio"
    for pi, phase in enumerate(plan):
        pk = nn.split_keys(keys[2 + pi], phase.n_groups)
        groups = []
        for g in range(phase.n_groups):
            gk = nn.split_keys(pk[g], len(phase.kinds))
            groups.append({
                f"slot{j}": blocks.slot_init(gk[j], cfg, kind, ffn, dtype, cross=cross)
                for j, (kind, ffn) in enumerate(zip(phase.kinds, phase.ffns))
            })
        p[f"phase{pi}"] = nn.stack_layers(groups)
    if cfg.family == "hybrid":          # zamba2 tied shared attn+MLP block
        p["shared"] = blocks.slot_init(keys[-2], cfg, "global", "mlp", dtype)
    if cfg.family == "audio":           # whisper encoder stack
        ek = nn.split_keys(keys[-1], cfg.encoder_layers)
        p["encoder"] = nn.stack_layers([
            blocks.slot_init(ek[i], cfg, "global", "mlp", dtype)
            for i in range(cfg.encoder_layers)])
    return p


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------
def _embed(params, cfg: ArchConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return x


def _head(params, cfg: ArchConfig, x):
    x = nn.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["embed"].astype(x.dtype))
    return jnp.einsum("...d,dv->...v", x, params["lm_head"].astype(x.dtype))


def _encoder(params, cfg: ArchConfig, frames):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    x = frames.astype(cfg.compute_dtype)
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1])[None], frames.shape[:2])
    x = x + sinusoid(pos, cfg.d_model).astype(x.dtype)

    def body(carry, gp):
        h = carry
        mix = attn.gqa_forward(gp["mixer"], nn.rms_norm(h, gp["norm1"], cfg.norm_eps),
                               pos, cfg, causal=False)
        h = h + mix
        h = h + blocks.mlp_forward(gp["ffn"], nn.rms_norm(h, gp["norm2"], cfg.norm_eps), cfg)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"],
                        unroll=True if cfg.scan_unroll else 1)
    return x


def _positions_for(cfg: ArchConfig, batch) -> jax.Array:
    tokens = batch["tokens"]
    if cfg.use_mrope:
        return batch["mrope_positions"]
    b, s = tokens.shape
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def _inputs(params, cfg: ArchConfig, batch):
    x = _embed(params, cfg, batch["tokens"])
    if cfg.family == "vlm" and "vision_embeds" in batch:
        nv = batch["vision_embeds"].shape[1]
        x = x.at[:, :nv].set(batch["vision_embeds"].astype(x.dtype))
    if cfg.family == "audio":
        b, s = batch["tokens"].shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x = x + sinusoid(pos, cfg.d_model).astype(x.dtype)
    return constrain(x, "batch", None, None)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(params, cfg: ArchConfig, batch, *, collect_cache: bool = False,
            cache_len: int = 0):
    """Returns (logits (B,S,V), aux_loss, cache or None).

    When collect_cache, KV caches are emitted padded to `cache_len`
    (>= S) so decode can continue from the prefill."""
    params = nn.cast_tree(params, cfg.compute_dtype)   # mixed precision
    plan = blocks.build_plan(cfg)
    positions = _positions_for(cfg, batch)
    x = _inputs(params, cfg, batch)
    enc_out = _encoder(params, cfg, batch["frames"]) if cfg.family == "audio" else None
    aux = jnp.zeros((), jnp.float32)
    caches: dict = {}

    for pi, phase in enumerate(plan):
        stacked = params[f"phase{pi}"]

        def group_fn(carry, gp, phase=phase):
            h, a = carry
            gcache = {}
            for j, (kind, ffn) in enumerate(zip(phase.kinds, phase.ffns)):
                enc_kv = None
                if enc_out is not None:
                    enc_kv = attn.cross_kv(gp[f"slot{j}"]["cross"], enc_out)
                h, c, aj = blocks.slot_forward(
                    gp[f"slot{j}"], h, positions, cfg, kind, ffn,
                    collect_cache=collect_cache, enc_kv=enc_kv)
                if collect_cache:
                    c = _pad_cache(c, kind, cfg, cache_len)
                    if enc_kv is not None:
                        c = dict(c, cross_k=enc_kv[0], cross_v=enc_kv[1])
                    gcache[f"slot{j}"] = c
                a = a + aj
            if phase.shared_attn:
                w = _shared_window(cfg, cache_len)
                kind = "local" if w else "global"
                h, c, _ = blocks.slot_forward(
                    params["shared"], h, positions, cfg, kind, "mlp",
                    collect_cache=collect_cache)
                if collect_cache:
                    gcache["shared"] = _pad_cache(c, kind, cfg, cache_len, window=w)
            h = constrain(h, "batch", None, None)
            return (h, a), (gcache if collect_cache else None)

        body = jax.checkpoint(group_fn) if cfg.remat else group_fn
        (x, aux), pc = jax.lax.scan(body, (x, aux), stacked,
                                    unroll=True if cfg.scan_unroll else 1)
        if collect_cache:
            caches[f"phase{pi}"] = pc

    logits = _head(params, cfg, x)
    if collect_cache and cfg.family == "audio":
        caches["enc_len"] = jnp.full((x.shape[0],), enc_out.shape[1], jnp.int32)
    return logits, aux, (caches if collect_cache else None)


def _shared_window(cfg: ArchConfig, cache_len: int) -> int:
    """Zamba2 long-context adaptation: window the tied attention block when
    the decode budget exceeds the training window (DESIGN.md)."""
    if cfg.family == "hybrid" and cache_len and cache_len > 65536:
        return cfg.sliding_window
    return 0


def _pad_cache(c: dict, kind: str, cfg: ArchConfig, cache_len: int, window: int = 0):
    """Pad prefill-emitted kv to the decode cache length (ring-aware)."""
    if kind not in ("global", "local", "mla") or not cache_len:
        return c
    if kind == "local" or window:
        w = window or cfg.sliding_window
        size = min(w, cache_len)
        out = {}
        for name in ("k", "v"):
            kv = c[name]
            s = kv.shape[1]
            if s >= size:
                # last `size` positions, placed at their ring slots
                tail = kv[:, -size:]
                pos = jnp.arange(s - size, s) % size
                out[name] = jnp.zeros((kv.shape[0], size) + kv.shape[2:],
                                      kv.dtype).at[:, pos].set(tail)
            else:
                out[name] = jnp.pad(kv, ((0, 0), (0, size - s)) + ((0, 0),) * (kv.ndim - 2))
        for name in c:
            if name not in ("k", "v"):
                out[name] = c[name]
        return out
    out = {}
    for name, kv in c.items():
        s = kv.shape[1]
        out[name] = kv if s >= cache_len else jnp.pad(
            kv, ((0, 0), (0, cache_len - s)) + ((0, 0),) * (kv.ndim - 2))
    return out


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def decode_step(params, cfg: ArchConfig, tokens, positions, cache):
    """tokens: (B,1); positions: (B,) (or (B,3) M-RoPE) absolute position of
    the new token.  Returns (logits (B,V), new_cache).

    Formulation note (EXPERIMENTS.md §Perf C3, refuted): an in-place
    variant updating the layer-STACKED cache via chained scatters
    (blocks.slot_decode_stacked) measured 5x WORSE -- XLA lowers each
    full-stack scatter as a whole-buffer copy.  The scan-with-ys form
    below (slice scatter + ys restack, ~2 cache copies/step) is the
    better-measured baseline and is kept."""
    params = nn.cast_tree(params, cfg.compute_dtype)   # mixed precision
    plan = blocks.build_plan(cfg)
    x = _embed(params, cfg, tokens)
    if cfg.family == "audio":
        x = x + sinusoid(positions[:, None], cfg.d_model).astype(x.dtype)
    x = constrain(x, "batch", None, None)
    new_cache: dict = {}

    for pi, phase in enumerate(plan):
        stacked = params[f"phase{pi}"]
        pcache = cache[f"phase{pi}"]

        def group_fn(h, xs, phase=phase):
            gp, gc = xs
            out_c = {}
            for j, (kind, ffn) in enumerate(zip(phase.kinds, phase.ffns)):
                sc = dict(gc[f"slot{j}"])
                enc_kv = None
                if cfg.family == "audio":
                    enc_kv = (sc.pop("cross_k"), sc.pop("cross_v"))
                h, nc = blocks.slot_decode(gp[f"slot{j}"], h, sc, positions, cfg,
                                           kind, ffn, enc_kv=enc_kv)
                if enc_kv is not None:
                    nc = dict(nc, cross_k=enc_kv[0], cross_v=enc_kv[1])
                out_c[f"slot{j}"] = nc
            if phase.shared_attn:
                # window iff the cache was built windowed (ring size < budget)
                w = cfg.sliding_window if gc["shared"]["k"].shape[1] <= cfg.sliding_window \
                    else 0
                kind = "local" if w else "global"
                h, nc = blocks.slot_decode(params["shared"], h, gc["shared"],
                                           positions, cfg, kind, "mlp")
                out_c["shared"] = nc
            return h, out_c

        x, pc = jax.lax.scan(group_fn, x, (stacked, pcache),
                             unroll=True if cfg.scan_unroll else 1)
        new_cache[f"phase{pi}"] = pc

    if cfg.family == "audio":
        new_cache["enc_len"] = cache["enc_len"]
    logits = _head(params, cfg, x[:, 0])
    return logits, new_cache


def prefill_chunk(params, cfg: ArchConfig, tokens, positions, cache):
    """Chunked prefill: C prompt tokens at once against the decode cache.

    tokens: (B,C); positions: (B,C) (or (B,3,C) M-RoPE) absolute positions,
    contiguous ascending per row.  Returns (logits (B,C,V), new_cache).

    This is the decode twin of `forward`: the per-phase scan structure is
    decode_step's, but each slot consumes the whole chunk -- attention kinds
    write their C cache rows and attend with decode-exact masking
    (attention.gqa_prefill / mla_prefill), recurrent kinds scan the exact
    decode recurrence (ssm.*_prefill).  A P-token prompt therefore costs
    O(P/C) calls instead of P decode steps, and the oracle suite
    (tests/test_prefill_oracle.py) pins its outputs to the teacher-forced
    decode_step reference."""
    if cfg.family == "audio":
        raise NotImplementedError("chunked prefill: audio enc-dec unsupported")
    params = nn.cast_tree(params, cfg.compute_dtype)   # mixed precision
    plan = blocks.build_plan(cfg)
    x = _embed(params, cfg, tokens)
    x = constrain(x, "batch", None, None)
    new_cache: dict = {}

    for pi, phase in enumerate(plan):
        stacked = params[f"phase{pi}"]
        pcache = cache[f"phase{pi}"]

        def group_fn(h, xs, phase=phase):
            gp, gc = xs
            out_c = {}
            for j, (kind, ffn) in enumerate(zip(phase.kinds, phase.ffns)):
                h, nc = blocks.slot_prefill(gp[f"slot{j}"], h, gc[f"slot{j}"],
                                            positions, cfg, kind, ffn)
                out_c[f"slot{j}"] = nc
            if phase.shared_attn:
                w = cfg.sliding_window if gc["shared"]["k"].shape[1] <= cfg.sliding_window \
                    else 0
                kind = "local" if w else "global"
                h, nc = blocks.slot_prefill(params["shared"], h, gc["shared"],
                                            positions, cfg, kind, "mlp")
                out_c["shared"] = nc
            return h, out_c

        x, pc = jax.lax.scan(group_fn, x, (stacked, pcache),
                             unroll=True if cfg.scan_unroll else 1)
        new_cache[f"phase{pi}"] = pc

    logits = _head(params, cfg, x)
    return logits, new_cache


def init_cache(cfg: ArchConfig, batch: int, length: int) -> Any:
    """Zeroed decode caches (structure mirrors forward(collect_cache))."""
    plan = blocks.build_plan(cfg)
    cdt = cfg.compute_dtype
    cache: dict = {}
    for pi, phase in enumerate(plan):
        pc = {}
        for j, kind in enumerate(phase.kinds):
            shp = blocks.slot_cache_shape(cfg, kind, batch, length)
            dt = blocks.cache_dtypes(kind, cdt)
            c = {k: jnp.zeros((phase.n_groups,) + v, dt) for k, v in shp.items()}
            if cfg.family == "audio":
                hkv, hd = cfg.n_kv_heads, cfg.head_dim
                c["cross_k"] = jnp.zeros((phase.n_groups, batch, cfg.encoder_len, hkv, hd), cdt)
                c["cross_v"] = jnp.zeros((phase.n_groups, batch, cfg.encoder_len, hkv, hd), cdt)
            pc[f"slot{j}"] = c
        if phase.shared_attn:
            w = _shared_window(cfg, length)
            shp = blocks.slot_cache_shape(
                cfg, "local" if w else "global", batch, length)
            pc["shared"] = {k: jnp.zeros((phase.n_groups,) + v, cdt)
                            for k, v in shp.items()}
        cache[f"phase{pi}"] = pc
    if cfg.family == "audio":
        cache["enc_len"] = jnp.zeros((batch,), jnp.int32)
    return cache
