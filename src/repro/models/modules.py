"""Tiny functional param system: initializers + pytree helpers (no flax)."""
from __future__ import annotations

import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of jnp arrays


def dense_init(key, shape: Sequence[int], fan_in: int | None = None, dtype=jnp.float32):
    """Truncated-normal init scaled by 1/sqrt(fan_in) (LeCun-ish)."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def stack_layers(layer_params: list[Params]) -> Params:
    """Stack a list of identically-structured param trees along axis 0
    (the lax.scan-over-layers representation)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def layer_slice(stacked: Params, i) -> Params:
    """Dynamic-index layer *i* out of a stacked param tree (inside scan)."""
    return jax.tree_util.tree_map(lambda x: x[i], stacked)


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))


def cast_tree(params: Params, dtype) -> Params:
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params
    )


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def swiglu(x, gate):
    return jax.nn.silu(gate) * x


def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


def gelu(x):
    return jax.nn.gelu(x)


ACTIVATIONS: dict[str, Callable] = {
    "swiglu": swiglu,  # handled specially (two-input) in layers
    "gelu": gelu,
    "squared_relu": squared_relu,
    "silu": jax.nn.silu,
}
