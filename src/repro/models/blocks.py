"""Block assembly: per-layer "slots" (mixer + ffn), grouped into scan phases.

Every architecture is a sequence of layers; each layer is
    x = x + mixer(norm1(x));  x = x + ffn(norm2(x))        (ffn optional)
with mixer in {global, local, mla, mamba2, mlstm, slstm} and ffn in
{mlp, moe, none}.  Layers are grouped by the repeating pattern (gemma3:
5 local + 1 global; xlstm: 7 mlstm + 1 slstm; ...) and each phase is a
jax.lax.scan over stacked group params -- compact HLO so the 512-device
dry-run compiles on CPU in reasonable time.

Zamba2's weight-TIED shared attention block is applied after each group of
`shared_attn_every` mamba layers; its params live outside the scan stack
(closure), while its per-invocation KV caches are stacked per group.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import attention as attn
from . import modules as nn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .sharding import constrain

Params = Any

MIXER_KINDS = ("global", "local", "mla", "mamba2", "mlstm", "slstm")
FFN_KINDS = ("mlp", "moe", "none")


@dataclasses.dataclass(frozen=True)
class Phase:
    kinds: tuple          # mixer kind per slot in the group
    ffns: tuple           # ffn kind per slot
    n_groups: int
    shared_attn: bool = False   # zamba2: tied attention block after each group


def build_plan(cfg: ArchConfig) -> list[Phase]:
    """Derive the scan-phase plan from the config."""
    L = cfg.n_layers
    if cfg.family == "ssm":                          # xlstm
        per = cfg.slstm_every or L
        kinds = tuple("mlstm" if (i + 1) % per else "slstm" for i in range(per))
        assert L % per == 0, "xlstm layer count must tile the sLSTM period"
        return [Phase(kinds, ("none",) * per, L // per)]
    if cfg.family == "hybrid":                       # zamba2
        per = cfg.shared_attn_every
        full, rem = divmod(L, per)
        phases = [Phase(("mamba2",) * per, ("none",) * per, full, shared_attn=True)]
        if rem:
            phases.append(Phase(("mamba2",) * rem, ("none",) * rem, 1))
        return phases
    ffn = "moe" if cfg.n_experts else "mlp"
    pattern = cfg.block_pattern
    phases = []
    if cfg.n_experts and cfg.first_layer_dense:      # deepseek: dense layer 0
        phases.append(Phase((pattern[0],), ("mlp",), 1))
        L -= 1
    per = len(pattern)
    full, rem = divmod(L, per)
    if full:
        phases.append(Phase(tuple(pattern), (ffn,) * per, full))
    if rem:
        phases.append(Phase(tuple(pattern[:rem]), (ffn,) * rem, 1))
    return phases


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ArchConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = nn.split_keys(key, 3)
    if cfg.mlp_act == "swiglu":
        return {"w_gate": nn.dense_init(ks[0], (d, f), dtype=dtype),
                "w_up": nn.dense_init(ks[1], (d, f), dtype=dtype),
                "w_down": nn.dense_init(ks[2], (f, d), fan_in=f, dtype=dtype)}
    return {"w_up": nn.dense_init(ks[0], (d, f), dtype=dtype),
            "w_down": nn.dense_init(ks[1], (f, d), fan_in=f, dtype=dtype)}


def mlp_forward(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["w_up"])
    if cfg.mlp_act == "swiglu":
        h = nn.swiglu(h, jnp.einsum("...d,df->...f", x, p["w_gate"]))
    else:
        h = nn.ACTIVATIONS[cfg.mlp_act](h)
    h = constrain(h, "batch", None, "model")
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# Slots
# ---------------------------------------------------------------------------
_MIXER_INIT = {
    "global": attn.gqa_init, "local": attn.gqa_init, "mla": attn.mla_init,
    "mamba2": ssm_mod.mamba2_init, "mlstm": ssm_mod.mlstm_init,
    "slstm": ssm_mod.slstm_init,
}


def slot_init(key, cfg: ArchConfig, kind: str, ffn: str, dtype,
              cross: bool = False) -> Params:
    ks = nn.split_keys(key, 4)
    p = {"norm1": jnp.zeros((cfg.d_model,), dtype),
         "mixer": _MIXER_INIT[kind](ks[0], cfg, dtype)}
    if ffn != "none":
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["ffn"] = (moe_mod.moe_init(ks[1], cfg, dtype) if ffn == "moe"
                    else mlp_init(ks[1], cfg, dtype))
    if cross:   # whisper decoder: cross-attention sub-layer
        p["norm_x"] = jnp.zeros((cfg.d_model,), dtype)
        p["cross"] = attn.cross_init(ks[2], cfg, dtype)
    return p


def _mixer_forward(p, x, positions, cfg, kind, collect_cache: bool):
    window = cfg.sliding_window if kind == "local" else 0
    if kind in ("global", "local"):
        if collect_cache:
            out, (k, v) = attn.gqa_forward(p, x, positions, cfg, window=window,
                                           return_kv=True)
            return out, {"k": k, "v": v}
        return attn.gqa_forward(p, x, positions, cfg, window=window), None
    if kind == "mla":
        if collect_cache:
            out, c = attn.mla_forward(p, x, positions, cfg, return_cache=True)
            return out, c
        return attn.mla_forward(p, x, positions, cfg), None
    fwd = {"mamba2": ssm_mod.mamba2_forward, "mlstm": ssm_mod.mlstm_forward,
           "slstm": ssm_mod.slstm_forward}[kind]
    if collect_cache:
        return fwd(p, x, cfg, return_state=True)
    return fwd(p, x, cfg), None


def _mixer_decode(p, x, cache, positions, cfg, kind):
    if kind in ("global", "local"):
        window = cfg.sliding_window if kind == "local" else 0
        return attn.gqa_decode(p, x, cache, positions, cfg, window=window)
    if kind == "mla":
        return attn.mla_decode(p, x, cache, positions, cfg)
    dec = {"mamba2": ssm_mod.mamba2_decode, "mlstm": ssm_mod.mlstm_decode,
           "slstm": ssm_mod.slstm_decode}[kind]
    return dec(p, x, cache, cfg)


def slot_forward(p: Params, x: jax.Array, positions, cfg: ArchConfig,
                 kind: str, ffn: str, *, collect_cache: bool = False,
                 enc_kv=None):
    mix_out, cache = _mixer_forward(p["mixer"], nn.rms_norm(x, p["norm1"], cfg.norm_eps),
                                    positions, cfg, kind, collect_cache)
    x = x + mix_out
    if enc_kv is not None:
        x = x + attn.cross_forward(p["cross"], nn.rms_norm(x, p["norm_x"], cfg.norm_eps),
                                   enc_kv, cfg)
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = nn.rms_norm(x, p["norm2"], cfg.norm_eps)
        if ffn == "moe":
            y, aux = moe_mod.moe_forward(p["ffn"], h, cfg)
        else:
            y = mlp_forward(p["ffn"], h, cfg)
        x = x + y
    return x, cache, aux


def slot_decode(p: Params, x: jax.Array, cache, positions, cfg: ArchConfig,
                kind: str, ffn: str, *, enc_kv=None):
    mix_out, new_cache = _mixer_decode(
        p["mixer"], nn.rms_norm(x, p["norm1"], cfg.norm_eps), cache, positions, cfg, kind)
    x = x + mix_out
    if enc_kv is not None:
        x = x + attn.cross_decode(p["cross"], nn.rms_norm(x, p["norm_x"], cfg.norm_eps),
                                  enc_kv, cfg)
    if ffn != "none":
        h = nn.rms_norm(x, p["norm2"], cfg.norm_eps)
        y = (moe_mod.moe_forward(p["ffn"], h, cfg)[0] if ffn == "moe"
             else mlp_forward(p["ffn"], h, cfg))
        x = x + y
    return x, new_cache


def _mixer_prefill(p, x, cache, positions, cfg, kind):
    if kind in ("global", "local"):
        window = cfg.sliding_window if kind == "local" else 0
        return attn.gqa_prefill(p, x, cache, positions, cfg, window=window)
    if kind == "mla":
        return attn.mla_prefill(p, x, cache, positions, cfg)
    pre = {"mamba2": ssm_mod.mamba2_prefill, "mlstm": ssm_mod.mlstm_prefill,
           "slstm": ssm_mod.slstm_prefill}[kind]
    return pre(p, x, cache, cfg)


def slot_prefill(p: Params, x: jax.Array, cache, positions, cfg: ArchConfig,
                 kind: str, ffn: str):
    """Chunked-prefill twin of slot_decode: C tokens, decode-cache layout.

    Attention kinds batch all C queries against the cache with decode-exact
    masking; recurrent kinds scan the exact decode recurrence.  FFN / norms
    are position-independent row ops and run batched."""
    mix_out, new_cache = _mixer_prefill(
        p["mixer"], nn.rms_norm(x, p["norm1"], cfg.norm_eps), cache,
        positions, cfg, kind)
    x = x + mix_out
    if ffn != "none":
        h = nn.rms_norm(x, p["norm2"], cfg.norm_eps)
        y = (moe_mod.moe_forward(p["ffn"], h, cfg, no_drop=True)[0]
             if ffn == "moe" else mlp_forward(p["ffn"], h, cfg))
        x = x + y
    return x, new_cache


def slot_decode_stacked(p: Params, x: jax.Array, stacked, g: int, positions,
                        cfg: ArchConfig, kind: str, ffn: str, *, enc_kv=None):
    """slot_decode against the layer-STACKED cache: attention kinds update
    in place via dynamic-update-slice (§Perf C3); recurrent kinds read the
    layer slice and write the (small) state back at group index g."""
    xn = nn.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        window = cfg.sliding_window if kind == "local" else 0
        mix_out, stacked = attn.gqa_decode_stacked(p["mixer"], xn, stacked, g,
                                                   positions, cfg, window=window)
    elif kind == "mla":
        mix_out, stacked = attn.mla_decode_stacked(p["mixer"], xn, stacked, g,
                                                   positions, cfg)
    else:
        dec = {"mamba2": ssm_mod.mamba2_decode, "mlstm": ssm_mod.mlstm_decode,
               "slstm": ssm_mod.slstm_decode}[kind]
        state_keys = slot_cache_shape(cfg, kind, 1, 1).keys()
        layer_state = {k: stacked[k][g] for k in state_keys}
        mix_out, new_state = dec(p["mixer"], xn, layer_state, cfg)
        stacked = dict(stacked, **{k: stacked[k].at[g].set(
            new_state[k].astype(stacked[k].dtype)) for k in state_keys})
    x = x + mix_out
    if enc_kv is not None:
        x = x + attn.cross_decode(p["cross"], nn.rms_norm(x, p["norm_x"], cfg.norm_eps),
                                  enc_kv, cfg)
    if ffn != "none":
        h = nn.rms_norm(x, p["norm2"], cfg.norm_eps)
        y = (moe_mod.moe_forward(p["ffn"], h, cfg)[0] if ffn == "moe"
             else mlp_forward(p["ffn"], h, cfg))
        x = x + y
    return x, stacked


def slot_cache_shape(cfg: ArchConfig, kind: str, batch: int, length: int):
    if kind == "global":
        return attn.gqa_cache_shape(cfg, batch, length)
    if kind == "local":
        return attn.gqa_cache_shape(cfg, batch, length, window=cfg.sliding_window)
    if kind == "mla":
        return attn.mla_cache_shape(cfg, batch, length)
    if kind == "mamba2":
        return ssm_mod.mamba2_cache_shape(cfg, batch)
    if kind == "mlstm":
        return ssm_mod.mlstm_cache_shape(cfg, batch)
    if kind == "slstm":
        return ssm_mod.slstm_cache_shape(cfg, batch)
    raise ValueError(kind)


def cache_dtypes(kind: str, compute_dtype):
    """SSM-ish states carry fp32; KV caches follow the compute dtype."""
    if kind in ("global", "local", "mla"):
        return compute_dtype
    return jnp.float32
