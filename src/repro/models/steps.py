"""Jitted entry points per architecture: train_step / prefill / serve_step,
plus loss and the ShapeDtypeStruct input_specs used by the dry-run.

Shape contract (system assignment):
  train_4k    -> train_step(params, opt_state, batch)
  prefill_32k -> prefill(params, batch)              (builds the KV cache)
  decode_32k, long_500k -> serve_step(params, cache, tokens, positions)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..optim import adamw
from . import lm
from .sharding import constrain

Params = Any


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE; labels==-1 masked out."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg: ArchConfig, batch):
    logits, aux, _ = lm.forward(params, cfg, batch)
    ce = softmax_xent(logits[:, :-1], batch["labels"][:, 1:])
    return ce + cfg.router_aux_weight * aux, {"ce": ce, "aux": aux}


def train_step(params, opt_state, batch, *, cfg: ArchConfig,
               opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    params, opt_state, opt_metrics = adamw.adamw_update(params, grads, opt_state, opt_cfg)
    return params, opt_state, {"loss": loss, **metrics, **opt_metrics}


def prefill(params, batch, *, cfg: ArchConfig, cache_len: int = 0):
    """Full-sequence forward emitting a decode cache (padded to cache_len)."""
    cache_len = cache_len or batch["tokens"].shape[1]
    logits, _, cache = lm.forward(params, cfg, batch, collect_cache=True,
                                  cache_len=cache_len)
    return logits[:, -1], cache


def serve_step(params, cache, tokens, positions, *, cfg: ArchConfig):
    """ONE new token per sequence against the cache. tokens: (B,1)."""
    return lm.decode_step(params, cfg, tokens, positions, cache)


def greedy_decode_loop(params, cache, first_token, start_pos, n_steps: int,
                       *, cfg: ArchConfig):
    """lax.scan'd greedy generation (serving substrate)."""
    def step(carry, _):
        tok, pos, cch = carry
        logits, cch = lm.decode_step(params, cfg, tok, pos, cch)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return (nxt, pos + 1, cch), nxt[:, 0]

    (_, _, cache), toks = jax.lax.scan(
        step, (first_token, start_pos, cache), None, length=n_steps)
    return toks.T, cache          # (B, n_steps)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins per (arch x shape) for the dry-run
# ---------------------------------------------------------------------------
def batch_spec(cfg: ArchConfig, batch: int, seq: int, *, train: bool) -> dict:
    i32 = jnp.int32
    spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
    if train:
        spec["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
    if cfg.use_mrope:
        spec["mrope_positions"] = jax.ShapeDtypeStruct((batch, 3, seq), i32)
    if cfg.family == "vlm":
        spec["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, min(cfg.n_vision_tokens, seq), cfg.d_model), cfg.compute_dtype)
    if cfg.family == "audio":
        spec["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_len, cfg.d_model), cfg.compute_dtype)
    return spec


def decode_specs(cfg: ArchConfig, batch: int, cache_len: int):
    i32 = jnp.int32
    tokens = jax.ShapeDtypeStruct((batch, 1), i32)
    pos_shape = (batch, 3) if cfg.use_mrope else (batch,)
    positions = jax.ShapeDtypeStruct(pos_shape, i32)
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, batch, cache_len))
    return tokens, positions, cache


def params_spec(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg))


def opt_state_spec(params_shape):
    return jax.eval_shape(adamw.init_opt_state, params_shape)
