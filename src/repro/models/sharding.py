"""Sharding substrate: logical-axis rules mapping params/activations onto the mesh.

The production mesh has axes ("data", "model") single-pod or
("pod", "data", "model") multi-pod (launch/mesh.py).  Model code never
touches jax.sharding directly -- it calls :func:`constrain` with *logical*
axis names; this module resolves them against the currently-active mesh.

Param sharding is rule-based: every parameter leaf has a descriptive key
(``wq``, ``w_down``, ``experts_up`` ...) and SHARDING_RULES maps that key to
a PartitionSpec *tail* applied to the trailing dims (leading stacked-layer
dims are None-padded).  GSPMD pads non-divisible dims, so e.g. 24 heads over
model=16 still lowers -- the waste shows up in the roofline flops ratio and
is hillclimbed in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()

# Logical axis -> mesh axis (or tuple of mesh axes).  "batch" spans the pod
# axis too when present so global_batch shards over every data-parallel chip.
LOGICAL_AXES = {
    "batch": ("pod", "data"),
    "model": ("model",),
    "expert": ("model",),   # expert-parallel rides the model axis
    None: None,
}

# "dp" profile (perf variant): the model axis carries batch instead --
# params replicated, no per-layer activation collectives.
DP_AXES = {
    "batch": ("pod", "data", "model"),
    "model": None,
    "expert": None,
    None: None,
}


def current_profile() -> str:
    return getattr(_state, "profile", "tp")


@contextlib.contextmanager
def use_profile(profile: str):
    prev = current_profile()
    _state.profile = profile
    try:
        yield
    finally:
        _state.profile = prev


def _axis_table():
    return DP_AXES if current_profile() == "dp" else LOGICAL_AXES


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Activate *mesh* for constrain()/param_shardings(). None deactivates."""
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def abstract_mesh(shape: Sequence[int], names: Sequence[str]):
    """Version-portable ``jax.sharding.AbstractMesh`` constructor.

    jax<=0.4.x takes a single ``((name, size), ...)`` tuple; jax>=0.5 takes
    ``(axis_sizes, axis_names)``.  Tests build abstract meshes for rule
    resolution without devices, so they go through this shim."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(names, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(names))


def _resolve(spec: Sequence[Any], mesh: Mesh) -> P:
    """Map logical axis names to mesh axes present on *mesh*."""
    table = _axis_table()
    out = []
    for ax in spec:
        mesh_axes = table.get(ax, (ax,) if ax else None)
        if mesh_axes is None:
            out.append(None)
            continue
        present = tuple(a for a in mesh_axes if a in mesh.axis_names)
        out.append(present if len(present) > 1 else (present[0] if present else None))
    return P(*out)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def fit_pspec(shape: tuple, spec: P, mesh: Mesh, *, relocate: bool = True,
              min_relocate_bytes: int = 0) -> P:
    """Make a PartitionSpec legal for *shape*: pjit argument shardings
    require exact divisibility (no GSPMD padding at the jit boundary), so
    non-dividing assignments are moved to the largest divisible unassigned
    dim (relocate=True) or dropped.

    min_relocate_bytes: skip relocation for small tensors -- replicating a
    9 MB attention projection is free, while relocating it to its *input*
    dim turns every consumer matmul into a partial-sum + all-reduce
    (EXPERIMENTS.md §Perf iteration A4)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    entries = entries[:len(shape)]
    # dedup: a mesh axis may appear once; keep the first (leftmost) use so
    # specs can express fallbacks like ("expert", ..., "model") where both
    # resolve to the model axis and only one survives
    used: set = set()
    for i, e in enumerate(entries):
        if e is None:
            continue
        axes = e if isinstance(e, tuple) else (e,)
        if any(a in used for a in axes):
            entries[i] = None
            continue
        if shape[i] % _axis_size(mesh, e) == 0:
            used.update(axes)
    homeless = []
    for i, e in enumerate(entries):
        if e is not None and shape[i] % _axis_size(mesh, e) != 0:
            homeless.append(e)
            entries[i] = None
    if relocate and min_relocate_bytes:
        elems = 1
        for d in shape:
            elems *= d
        if elems * 4 < min_relocate_bytes:
            relocate = False
    if relocate:
        placed: set = set()
        for cur in entries:
            if cur is not None:
                placed.update(cur if isinstance(cur, tuple) else (cur,))
        for e in homeless:
            axes = e if isinstance(e, tuple) else (e,)
            if any(a in placed for a in axes):
                continue            # fallback entry already claimed this axis
            cand = [i for i, (d, cur) in enumerate(zip(shape, entries))
                    if cur is None and d % _axis_size(mesh, e) == 0
                    and d >= _axis_size(mesh, e)]
            if cand:
                best = max(cand, key=lambda i: shape[i])
                entries[best] = e
                placed.update(axes)
    return P(*entries)


def constrain(x: jax.Array, *spec: Any) -> jax.Array:
    """with_sharding_constraint against the active mesh; no-op when none."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(spec) < x.ndim:
        spec = tuple(spec) + (None,) * (x.ndim - len(spec))
    fitted = fit_pspec(x.shape, _resolve(spec, mesh), mesh, relocate=False)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fitted))


# ---------------------------------------------------------------------------
# Parameter sharding rules.  Key -> PartitionSpec tail over the *trailing*
# dims of the leaf (leading layer-stack dims padded with None).
# ---------------------------------------------------------------------------
SHARDING_RULES: dict[str, tuple] = {
    # embeddings / output head: vocab over model
    "embed": ("model", None),
    "lm_head": (None, "model"),
    # attention: heads over model
    "wq": (None, "model", None),          # (D, Hq, hd)
    "wk": (None, "model", None),          # (D, Hkv, hd)
    "wv": (None, "model", None),
    "wo": ("model", None, None),          # (Hq, hd, D)
    # MLA (deepseek): low-rank kv path; shard the decompression over heads
    "w_dq": (None, None),                 # (D, q_lora) -- small, replicated
    "w_uq": (None, "model", None),        # (q_lora|D, Hq, qk_head)
    "w_dkv": (None, None),                # (D, kv_lora + rope) replicated (small)
    "w_uk": (None, "model", None),        # (kv_lora, Hq, qk_nope)
    "w_uv": (None, "model", None),        # (kv_lora, Hq, v_head)
    "w_qr": (None, "model", None),        # rope-part q proj
    # MLP
    "w_gate": (None, "model"),            # (D, F)
    "w_up": (None, "model"),
    "w_down": ("model", None),            # (F, D)
    # MoE: experts over model axis (expert-parallel)
    "router": (None, None),               # (D, E) small, replicated
    "experts_gate": ("expert", None, None),   # (E, D, F)
    "experts_up": ("expert", None, None),
    "experts_down": ("expert", None, None),   # (E, F, D)
    "shared_gate": (None, "model"),
    "shared_up": (None, "model"),
    "shared_down": ("model", None),
    # SSM / xLSTM: inner dim over model
    "in_proj": (None, "model"),           # (D, inner)
    "out_proj": ("model", None),          # (inner, D)
    "conv_w": (None, "model"),            # (k, inner)
    "conv_b": ("model",),
    "xbc_proj": (None, "model"),
    "dt_proj": (None, "model"),
    "A_log": ("model",),
    "D_skip": ("model",),
    "gate_proj": (None, "model"),
    "ssm_norm": ("model",),
    # sLSTM / mLSTM gates
    "w_i": (None, "model"), "w_f": (None, "model"), "w_o": (None, "model"),
    "w_z": (None, "model"), "w_qx": (None, "model"), "w_kx": (None, "model"),
    "w_vx": (None, "model"),
    "r_i": (None, None), "r_f": (None, None), "r_o": (None, None), "r_z": (None, None),
    # norms / scalars: replicated
    "scale": (None,), "bias": (None,), "b_i": (None,), "b_f": (None,),
    "b_o": (None,), "b_z": (None,), "alpha": (None,),
    # conv stubs / lenet
    "w": None, "b": None,
}


def leaf_spec(path: tuple, leaf: Any) -> tuple:
    """PartitionSpec entries for one param leaf, from its dict key."""
    key = None
    for p in reversed(path):
        name = getattr(p, "key", getattr(p, "name", None))
        if isinstance(name, str):
            key = name
            break
    rule = SHARDING_RULES.get(key)
    ndim = jnp.ndim(leaf) if not hasattr(leaf, "ndim") else leaf.ndim
    if rule is None:
        return (None,) * ndim
    rule = tuple(rule)
    if len(rule) > ndim:            # e.g. scalar stored where rule expects vector
        return (None,) * ndim
    return (None,) * (ndim - len(rule)) + rule


def param_pspecs(params: Any) -> Any:
    """Tree of PartitionSpec (logical names) mirroring *params*."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: P(*leaf_spec(path, leaf)), params
    )


def param_shardings(params: Any, mesh: Mesh, *,
                    min_relocate_bytes: int = 0) -> Any:
    """Tree of NamedSharding for *params* on *mesh* (resolving logical axes,
    fitted to divisibility with relocation to the largest divisible dim;
    tensors under min_relocate_bytes replicate instead of relocating)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, fit_pspec(tuple(leaf.shape), _resolve(leaf_spec(path, leaf), mesh),
                            mesh, min_relocate_bytes=min_relocate_bytes)),
        params,
    )
