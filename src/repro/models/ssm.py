"""SSM family blocks: Mamba2 (zamba2), mLSTM + sLSTM (xlstm).

All three expose (init, forward, decode_step, cache_shape):
  forward     -- full-sequence (train / prefill), chunkwise-parallel scans
  decode_step -- single-token recurrent update against carried state
State tensors are fp32 (recurrence stability); activations follow cfg.dtype.

TPU adaptation (DESIGN.md): the GPU selective-scan kernels become chunked
matmul scans (MXU work) -- Mamba2 via kernels/ssm_scan (Pallas) or the
chunked-jnp twin; mLSTM via an analogous stabilised chunked form below.
sLSTM is inherently sequential (scalar recurrence) and stays a lax.scan.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels import ops
from . import modules as nn
from .sharding import constrain

Params = Any


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================
def mamba2_init(key, cfg: ArchConfig, dtype) -> Params:
    d, inner, n, kconv = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    h = cfg.n_heads                       # ssm heads; head dim P = inner // h
    ks = nn.split_keys(key, 6)
    conv_dim = inner + 2 * n              # x, B, C all pass the causal conv
    return {
        "in_proj": nn.dense_init(ks[0], (d, inner), fan_in=d, dtype=dtype),      # gate z
        "xbc_proj": nn.dense_init(ks[1], (d, conv_dim), fan_in=d, dtype=dtype),
        "conv_w": nn.dense_init(ks[2], (kconv, conv_dim), fan_in=kconv, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_proj": nn.dense_init(ks[3], (d, h), fan_in=d, dtype=dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D_skip": jnp.ones((h,), dtype),
        "ssm_norm": jnp.zeros((inner,), dtype),
        "out_proj": nn.dense_init(ks[4], (inner, d), fan_in=inner, dtype=dtype),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. u: (B,S,C), w: (k,C)."""
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(up[:, i:i + u.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b


def mamba2_forward(p: Params, x: jax.Array, cfg: ArchConfig,
                   *, return_state: bool = False):
    b, s, d = x.shape
    inner, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_heads
    ph = inner // h
    z = jnp.einsum("bsd,di->bsi", x, p["in_proj"])
    xbc = jnp.einsum("bsd,dc->bsc", x, p["xbc_proj"])
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xin, Bm, Cm = jnp.split(xbc, [inner, inner + n], axis=-1)
    xin = constrain(xin, "batch", None, "model")
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, p["dt_proj"])
                         + p["dt_bias"].astype(x.dtype))
    A = -jnp.exp(p["A_log"])
    chunk_unroll = cfg.chunk_unroll if cfg.chunk_unroll is not None else cfg.scan_unroll
    y, h_final = ops.ssm_scan(xin.reshape(b, s, h, ph), dt, A, Bm, Cm,
                              chunk=cfg.ssm_chunk, use_kernel=cfg.use_kernels,
                              unroll=chunk_unroll)
    y = y.reshape(b, s, inner) + xin * jnp.repeat(p["D_skip"], ph)[None, None, :]
    y = nn.rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    if return_state:
        # conv tail: last (k-1) pre-activation conv inputs
        k = cfg.ssm_conv
        xbc_raw = jnp.einsum("bsd,dc->bsc", x, p["xbc_proj"])
        tail = xbc_raw[:, -(k - 1):, :] if s >= k - 1 else jnp.pad(
            xbc_raw, ((0, 0), (k - 1 - s, 0), (0, 0)))
        return out, {"ssm": h_final, "conv": tail.astype(jnp.float32)}
    return out


def mamba2_decode(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig):
    """x: (B,1,D); cache {ssm:(B,H,P,N) f32, conv:(B,k-1,convdim) f32}."""
    b = x.shape[0]
    inner, n, h, k = cfg.d_inner, cfg.ssm_state, cfg.n_heads, cfg.ssm_conv
    ph = inner // h
    z = jnp.einsum("bsd,di->bsi", x, p["in_proj"])[:, 0]
    xbc_new = jnp.einsum("bsd,dc->bsc", x, p["xbc_proj"])[:, 0]    # (B,C)
    window = jnp.concatenate([cache["conv"], xbc_new[:, None, :].astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(x.dtype), p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    xin, Bm, Cm = jnp.split(xbc, [inner, inner + n], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", x, p["dt_proj"])[:, 0]
                         + p["dt_bias"].astype(x.dtype))           # (B,H)
    A = -jnp.exp(p["A_log"])
    hstate = cache["ssm"]
    decay = jnp.exp(A[None, :] * dt.astype(jnp.float32))           # (B,H)
    inject = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(jnp.float32),
                        xin.reshape(b, h, ph).astype(jnp.float32),
                        Bm.astype(jnp.float32))
    hstate = hstate * decay[..., None, None] + inject
    y = jnp.einsum("bhpn,bn->bhp", hstate, Cm.astype(jnp.float32)).astype(x.dtype)
    y = y.reshape(b, inner) + xin * jnp.repeat(p["D_skip"], ph)[None, :]
    y = nn.rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None, :]
    return out, {"ssm": hstate, "conv": window[:, 1:, :]}


def mamba2_prefill(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig):
    """Chunk prefill: scan the exact decode recurrence over C tokens.

    Bit-identical to C successive `mamba2_decode` calls (the chunkwise-
    parallel `mamba2_forward` is NOT -- different reduction order)."""
    def step(carry, xt):                                           # xt: (B,D)
        out, new = mamba2_decode(p, xt[:, None, :], carry, cfg)
        return new, out[:, 0]

    carry, ys = jax.lax.scan(step, cache, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), carry


def mamba2_cache_shape(cfg: ArchConfig, batch: int):
    inner, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_heads
    return {"ssm": (batch, h, inner // h, n),
            "conv": (batch, cfg.ssm_conv - 1, inner + 2 * n)}


# ===========================================================================
# mLSTM (xLSTM matrix memory) -- chunkwise-parallel stabilised form
# ===========================================================================
def mlstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    ks = nn.split_keys(key, 7)
    return {
        "w_qx": nn.dense_init(ks[0], (d, h * hd), fan_in=d, dtype=dtype),
        "w_kx": nn.dense_init(ks[1], (d, h * hd), fan_in=d, dtype=dtype),
        "w_vx": nn.dense_init(ks[2], (d, h * hd), fan_in=d, dtype=dtype),
        "w_i": nn.dense_init(ks[3], (d, h), fan_in=d, dtype=dtype),
        "w_f": nn.dense_init(ks[4], (d, h), fan_in=d, dtype=dtype),
        "b_i": jnp.zeros((h,), dtype),
        "b_f": jnp.full((h,), 3.0, dtype),        # forget-gate bias ~ remember
        "w_o": nn.dense_init(ks[5], (d, h * hd), fan_in=d, dtype=dtype),
        "out_proj": nn.dense_init(ks[6], (h * hd, d), fan_in=d, dtype=dtype),
        "scale": jnp.zeros((d,), dtype),          # pre-out groupnorm-ish scale
    }


def _mlstm_chunked(q, k, v, logi, logf, chunk: int, state=None, unroll: bool = False):
    """Stabilised chunkwise mLSTM. q,k,v: (B,S,H,D); logi/logf: (B,S,H) fp32.

    Returns (y (B,S,H,D), state (C,n,m)).  Matches kernels.ref.mlstm_scan_ref
    (y is stabiliser-invariant)."""
    b, s, h, d = q.shape
    t = min(chunk, s)
    pad = (-s) % t
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
    nc = q.shape[1] // t
    qf = (q.astype(jnp.float32) * d ** -0.5).reshape(b, nc, t, h, d)
    kf = k.astype(jnp.float32).reshape(b, nc, t, h, d)
    vf = v.astype(jnp.float32).reshape(b, nc, t, h, d)
    li = logi.reshape(b, nc, t, h)
    lf = logf.reshape(b, nc, t, h)
    tri = jnp.tril(jnp.ones((t, t), jnp.float32))

    if state is None:
        state = (jnp.zeros((b, h, d, d), jnp.float32),
                 jnp.zeros((b, h, d), jnp.float32),
                 jnp.full((b, h), -1e30, jnp.float32))

    def chunk_step(carry, args):
        C, nvec, m = carry
        qc, kc, vc, lic, lfc = args                    # (B,t,H,*)
        bcum = jnp.cumsum(lfc, axis=1)                 # (B,t,H) cumulative logf
        # intra-chunk log weights w[t,s] = bcum_t - bcum_s + li_s  (s<=t)
        wlog = bcum[:, :, None, :] - bcum[:, None, :, :] + lic[:, None, :, :]
        wlog = jnp.where(tri[None, :, :, None] > 0, wlog, -jnp.inf)
        glog = bcum + m[:, None, :]                    # state contribution decay
        m_row = jnp.maximum(jnp.max(wlog, axis=2), glog)           # (B,t,H)
        m_row = jnp.maximum(m_row, -1e30)
        wexp = jnp.exp(wlog - m_row[:, :, None, :])                # (B,t,s,H)
        gexp = jnp.exp(glog - m_row)                               # (B,t,H)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * wexp
        y_intra = jnp.einsum("btsh,bshd->bthd", scores, vc)
        y_state = gexp[..., None] * jnp.einsum("bhde,bthe->bthd", C, qc)
        nq = (jnp.einsum("btsh,bshd->bthd", wexp, kc) * qc).sum(-1) \
            + gexp * jnp.einsum("bthd,bhd->bth", qc, nvec)
        denom = jnp.maximum(jnp.abs(nq), jnp.exp(-m_row))
        y = (y_intra + y_state) / denom[..., None]
        # carry update (end of chunk), stabilised at m_new
        m_new = jnp.maximum(bcum[:, -1] + m, jnp.max(lic + (bcum[:, -1:] - bcum), axis=1))
        c_decay = jnp.exp(bcum[:, -1] + m - m_new)                 # (B,H)
        inj_w = jnp.exp(lic + (bcum[:, -1:] - bcum) - m_new[:, None])  # (B,t,H)
        C_new = C * c_decay[..., None, None] + jnp.einsum(
            "bthd,bthe,bth->bhde", vc, kc, inj_w)
        n_new = nvec * c_decay[..., None] + jnp.einsum("bthd,bth->bhd", kc, inj_w)
        return (C_new, n_new, m_new), y

    args = tuple(a.transpose(1, 0, *range(2, a.ndim)) for a in (qf, kf, vf, li, lf))
    state, ys = jax.lax.scan(chunk_step, state, args, unroll=True if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * t, h, d)[:, :s]
    return y, state


def mlstm_forward(p: Params, x: jax.Array, cfg: ArchConfig,
                  *, return_state: bool = False):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    q = jnp.einsum("bsd,de->bse", x, p["w_qx"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", x, p["w_kx"]).reshape(b, s, h, hd)
    v = jnp.einsum("bsd,de->bse", x, p["w_vx"]).reshape(b, s, h, hd)
    q = constrain(q, "batch", None, "model")
    logi = (jnp.einsum("bsd,dh->bsh", x, p["w_i"]) + p["b_i"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        (jnp.einsum("bsd,dh->bsh", x, p["w_f"]) + p["b_f"]).astype(jnp.float32))
    if cfg.use_kernels and not return_state:
        from ..kernels.mlstm_scan import mlstm_scan as _mlstm_pallas
        y = _mlstm_pallas(q, k, v, logi, logf, chunk=cfg.ssm_chunk,
                          interpret=jax.default_backend() != "tpu")
        state = None
    else:
        chunk_unroll = cfg.chunk_unroll if cfg.chunk_unroll is not None \
            else cfg.scan_unroll
        y, state = _mlstm_chunked(q, k, v, logi, logf, cfg.ssm_chunk,
                                  unroll=chunk_unroll)
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["w_o"]))
    y = y.reshape(b, s, h * hd).astype(x.dtype) * o
    out = jnp.einsum("bse,ed->bsd", nn.rms_norm(y, p["scale"], cfg.norm_eps),
                     p["out_proj"])
    if return_state:
        return out, {"C": state[0], "n": state[1], "m": state[2]}
    return out


def mlstm_decode(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig):
    """One-step recurrent mLSTM. cache {C:(B,H,D,D), n:(B,H,D), m:(B,H)}."""
    b, _, d = x.shape
    h = cfg.n_heads
    hd = d // h
    q = jnp.einsum("bsd,de->bse", x, p["w_qx"])[:, 0].reshape(b, h, hd).astype(jnp.float32)
    k = jnp.einsum("bsd,de->bse", x, p["w_kx"])[:, 0].reshape(b, h, hd).astype(jnp.float32)
    v = jnp.einsum("bsd,de->bse", x, p["w_vx"])[:, 0].reshape(b, h, hd).astype(jnp.float32)
    logi = (jnp.einsum("bsd,dh->bsh", x, p["w_i"])[:, 0] + p["b_i"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        (jnp.einsum("bsd,dh->bsh", x, p["w_f"])[:, 0] + p["b_f"]).astype(jnp.float32))
    C, nvec, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(logf + m, logi)
    fe = jnp.exp(logf + m - m_new)
    ie = jnp.exp(logi - m_new)
    C = C * fe[..., None, None] + ie[..., None, None] * jnp.einsum("bhd,bhe->bhde", v, k)
    nvec = nvec * fe[..., None] + ie[..., None] * k
    qs = q * hd ** -0.5
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", nvec, qs)), jnp.exp(-m_new))
    y = jnp.einsum("bhde,bhe->bhd", C, qs) / denom[..., None]
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, p["w_o"])[:, 0])
    y = y.reshape(b, h * hd).astype(x.dtype) * o
    out = jnp.einsum("be,ed->bd", nn.rms_norm(y, p["scale"], cfg.norm_eps),
                     p["out_proj"])[:, None, :]
    return out, {"C": C, "n": nvec, "m": m_new}


def mlstm_prefill(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig):
    """Chunk prefill: scan the exact one-step recurrence (decode twin)."""
    def step(carry, xt):
        out, new = mlstm_decode(p, xt[:, None, :], carry, cfg)
        return new, out[:, 0]

    carry, ys = jax.lax.scan(step, cache, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), carry


def mlstm_cache_shape(cfg: ArchConfig, batch: int):
    h = cfg.n_heads
    hd = cfg.d_model // h
    return {"C": (batch, h, hd, hd), "n": (batch, h, hd), "m": (batch, h)}


# ===========================================================================
# sLSTM (scalar memory, sequential)
# ===========================================================================
def slstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    ks = nn.split_keys(key, 9)
    p = {"out_proj": nn.dense_init(ks[8], (h * hd, d), fan_in=d, dtype=dtype),
         "scale": jnp.zeros((d,), dtype)}
    for name, kk in zip(("w_i", "w_f", "w_z", "w_o"), ks[:4]):
        p[name] = nn.dense_init(kk, (d, h * hd), fan_in=d, dtype=dtype)
    for name, kk in zip(("r_i", "r_f", "r_z", "r_o"), ks[4:8]):
        # block-diagonal recurrent weights: per-head (hd, hd)
        p[name] = nn.dense_init(kk, (h, hd, hd), fan_in=hd, dtype=dtype)
    p["b_i"] = jnp.zeros((h * hd,), dtype)
    p["b_f"] = jnp.full((h * hd,), 3.0, dtype)
    return p


def _slstm_step(p, cfg, carry, xt):
    """xt: (B, D_in-projected gates preacts computed outside for speed)."""
    c, n, m, hprev = carry                                        # (B,H,hd) each
    b = hprev.shape[0]
    h_heads, hd = hprev.shape[1], hprev.shape[2]
    xi, xf, xz, xo = xt                                           # (B, H*hd) preacts

    def rec(w, hv):
        return jnp.einsum("bhe,hef->bhf", hv, w)

    i_pre = xi.reshape(b, h_heads, hd) + rec(p["r_i"], hprev)
    f_pre = xf.reshape(b, h_heads, hd) + rec(p["r_f"], hprev)
    z_pre = xz.reshape(b, h_heads, hd) + rec(p["r_z"], hprev)
    o_pre = xo.reshape(b, h_heads, hd) + rec(p["r_o"], hprev)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    logi = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, logi)
    fe, ie = jnp.exp(logf + m - m_new), jnp.exp(logi - m_new)
    c_new = fe * c + ie * jnp.tanh(z_pre.astype(jnp.float32))
    n_new = fe * n + ie
    h_new = jax.nn.sigmoid(o_pre.astype(jnp.float32)) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new.astype(hprev.dtype))


def slstm_forward(p: Params, x: jax.Array, cfg: ArchConfig,
                  *, return_state: bool = False):
    b, s, d = x.shape
    h, hd = cfg.n_heads, d // cfg.n_heads
    pre = {g: jnp.einsum("bsd,de->bse", x, p[f"w_{g}"]) + p[f"b_{g}"]
           if f"b_{g}" in p else jnp.einsum("bsd,de->bse", x, p[f"w_{g}"])
           for g in ("i", "f", "z", "o")}
    init = (jnp.zeros((b, h, hd), jnp.float32), jnp.zeros((b, h, hd), jnp.float32),
            jnp.full((b, h, hd), -1e30, jnp.float32), jnp.zeros((b, h, hd), jnp.float32))

    def step(carry, t):
        xt = tuple(pre[g][:, t] for g in ("i", "f", "z", "o"))
        new = _slstm_step(p, cfg, carry, xt)
        return new, new[3]

    carry, hs = jax.lax.scan(step, init, jnp.arange(s))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, h * hd).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", nn.rms_norm(y, p["scale"], cfg.norm_eps),
                     p["out_proj"])
    if return_state:
        return out, {"c": carry[0], "n": carry[1], "m": carry[2], "h": carry[3]}
    return out


def slstm_decode(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig):
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    xt = tuple((jnp.einsum("bsd,de->bse", x, p[f"w_{g}"])[:, 0]
                + (p[f"b_{g}"] if f"b_{g}" in p else 0)) for g in ("i", "f", "z", "o"))
    c, n, m, hnew = _slstm_step(p, cfg, carry, xt)
    b, d = x.shape[0], x.shape[2]
    y = hnew.reshape(b, -1).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", nn.rms_norm(y, p["scale"], cfg.norm_eps),
                     p["out_proj"])[:, None, :]
    return out, {"c": c, "n": n, "m": m, "h": hnew}


def slstm_prefill(p: Params, x: jax.Array, cache: dict, cfg: ArchConfig):
    """Chunk prefill: scan the exact one-step recurrence (decode twin)."""
    def step(carry, xt):
        out, new = slstm_decode(p, xt[:, None, :], carry, cfg)
        return new, out[:, 0]

    carry, ys = jax.lax.scan(step, cache, x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2), carry


def slstm_cache_shape(cfg: ArchConfig, batch: int):
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    shp = (batch, h, hd)
    return {"c": shp, "n": shp, "m": shp, "h": shp}
