"""Katib analog: hyperparameter search with Grid / Random / Bayesian
algorithms + median-rule early stopping (the paper's §5.3/§6.1 substrate).

The Bayesian searcher is a from-scratch numpy Gaussian Process (RBF kernel,
expected improvement acquisition) over the unit-cube-normalised search
space -- no external deps.  All three algorithms drive the same Experiment
tracker, so the Table 2 benchmark (time vs max_trials per algorithm) falls
out of the trial log.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Any, Callable, Optional

import numpy as np

from ..core.experiment import Experiment, Trial
from ..checkpoint.store import ArtifactStore


# ---------------------------------------------------------------------------
# Search space
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Double:
    low: float
    high: float
    log: bool = False


@dataclasses.dataclass(frozen=True)
class Integer:
    low: int
    high: int


@dataclasses.dataclass(frozen=True)
class Categorical:
    choices: tuple


SearchSpace = dict  # name -> Double | Integer | Categorical


def _to_unit(space: SearchSpace, params: dict) -> np.ndarray:
    xs = []
    for name, p in space.items():
        v = params[name]
        if isinstance(p, Double):
            if p.log:
                xs.append((math.log(v) - math.log(p.low))
                          / (math.log(p.high) - math.log(p.low)))
            else:
                xs.append((v - p.low) / (p.high - p.low))
        elif isinstance(p, Integer):
            xs.append((v - p.low) / max(p.high - p.low, 1))
        else:
            xs.append(p.choices.index(v) / max(len(p.choices) - 1, 1))
    return np.array(xs)


def _from_unit(space: SearchSpace, x: np.ndarray) -> dict:
    params = {}
    for (name, p), u in zip(space.items(), x):
        u = float(np.clip(u, 0.0, 1.0))
        if isinstance(p, Double):
            if p.log:
                params[name] = math.exp(math.log(p.low)
                                        + u * (math.log(p.high) - math.log(p.low)))
            else:
                params[name] = p.low + u * (p.high - p.low)
        elif isinstance(p, Integer):
            params[name] = int(round(p.low + u * (p.high - p.low)))
        else:
            params[name] = p.choices[int(round(u * (len(p.choices) - 1)))]
    return params


# ---------------------------------------------------------------------------
# Suggestion algorithms
# ---------------------------------------------------------------------------
class GridSearch:
    """Exhaustive sequential sweep (paper: "grows exponentially ... very
    inefficient in time")."""
    name = "grid"

    def __init__(self, space: SearchSpace, max_trials: int, seed: int = 0):
        self.space = space
        k = len(space)
        per_dim = max(2, int(math.ceil(max_trials ** (1.0 / k))))
        axes = [np.linspace(0, 1, per_dim) for _ in range(k)]
        self.points = list(itertools.product(*axes))[:max_trials]
        self.i = 0

    def suggest(self, experiment: Experiment) -> Optional[dict]:
        if self.i >= len(self.points):
            return None
        x = np.array(self.points[self.i]); self.i += 1
        return _from_unit(self.space, x)


class RandomSearch:
    name = "random"

    def __init__(self, space: SearchSpace, max_trials: int, seed: int = 0):
        self.space = space
        self.max_trials = max_trials
        self.rng = np.random.default_rng(seed)
        self.i = 0

    def suggest(self, experiment: Experiment) -> Optional[dict]:
        if self.i >= self.max_trials:
            return None
        self.i += 1
        return _from_unit(self.space, self.rng.random(len(self.space)))


class BayesianSearch:
    """GP(RBF) + expected-improvement; first `n_init` trials random."""
    name = "bayesian"

    def __init__(self, space: SearchSpace, max_trials: int, seed: int = 0,
                 n_init: int = 3, n_candidates: int = 256,
                 length_scale: float = 0.25, noise: float = 1e-4):
        self.space = space
        self.max_trials = max_trials
        self.rng = np.random.default_rng(seed)
        self.n_init = n_init
        self.n_candidates = n_candidates
        self.ls = length_scale
        self.noise = noise
        self.i = 0

    def _kernel(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / self.ls ** 2)

    def suggest(self, experiment: Experiment) -> Optional[dict]:
        if self.i >= self.max_trials:
            return None
        self.i += 1
        done = [t for t in experiment.trials
                if t.status == "done" and experiment.objective(t) is not None]
        if len(done) < self.n_init:
            return _from_unit(self.space, self.rng.random(len(self.space)))
        X = np.stack([_to_unit(self.space, t.params) for t in done])
        y = np.array([experiment.objective(t) for t in done])
        sign = 1.0 if experiment.goal == "minimize" else -1.0
        y = sign * y
        mu_y, std_y = y.mean(), max(y.std(), 1e-9)
        yn = (y - mu_y) / std_y
        K = self._kernel(X, X) + self.noise * np.eye(len(X))
        Kinv = np.linalg.inv(K)
        cand = self.rng.random((self.n_candidates, len(self.space)))
        Ks = self._kernel(cand, X)                    # (C, N)
        mu = Ks @ Kinv @ yn
        var = np.maximum(1.0 - np.einsum("cn,nm,cm->c", Ks, Kinv, Ks), 1e-12)
        sigma = np.sqrt(var)
        best = yn.min()
        z = (best - mu) / sigma
        phi = np.exp(-0.5 * z ** 2) / math.sqrt(2 * math.pi)
        Phi = 0.5 * (1 + np.vectorize(math.erf)(z / math.sqrt(2)))
        ei = sigma * (z * Phi + phi)
        return _from_unit(self.space, cand[int(np.argmax(ei))])


ALGORITHMS = {"grid": GridSearch, "random": RandomSearch, "bayesian": BayesianSearch}


# ---------------------------------------------------------------------------
# Early stopping (Katib median-stop rule)
# ---------------------------------------------------------------------------
class MedianStop:
    """Stop a trial whose running objective is worse than the median of
    completed trials' objectives at the same step."""

    def __init__(self, min_trials: int = 3, min_steps: int = 2):
        self.min_trials = min_trials
        self.min_steps = min_steps

    def should_stop(self, experiment: Experiment, trial: Trial, step: int,
                    value: float) -> bool:
        if step < self.min_steps:
            return False
        peers = []
        for t in experiment.trials:
            if t.trial_id == trial.trial_id or not t.history:
                continue
            vals = [v for s, v in t.history if s <= step]
            if vals:
                peers.append(min(vals) if experiment.goal == "minimize" else max(vals))
        if len(peers) < self.min_trials:
            return False
        med = float(np.median(peers))
        return value > med if experiment.goal == "minimize" else value < med


# ---------------------------------------------------------------------------
# Katib driver
# ---------------------------------------------------------------------------
def tune(objective_fn: Callable[..., Any], space: SearchSpace, *,
         algorithm: str = "random", max_trials: int = 10,
         objective_key: str = "loss", goal: str = "minimize",
         early_stopping: Optional[MedianStop] = None, seed: int = 0,
         name: str = "katib", store: Optional[ArtifactStore] = None,
         goal_value: Optional[float] = None) -> Experiment:
    """Run a Katib experiment.

    objective_fn(params, report) -> metrics dict; `report(step, value)` is
    the intermediate-metric callback enabling early stopping.  Stops early
    globally when goal_value is reached (Katib "objective goal").
    """
    exp = Experiment(name=f"{name}-{algorithm}", objective_key=objective_key,
                     goal=goal, store=store)
    algo = ALGORITHMS[algorithm](space, max_trials, seed=seed)
    while True:
        params = algo.suggest(exp)
        if params is None:
            break
        trial = exp.new_trial(params)
        trial.status = "running"
        stopped = {"flag": False}

        def report(step: int, value: float, trial=trial, stopped=stopped):
            trial.report(step, value)
            if early_stopping and early_stopping.should_stop(exp, trial, step, value):
                stopped["flag"] = True
                raise EarlyStopped()

        t0 = time.perf_counter()
        try:
            metrics = objective_fn(params, report)
            trial.metrics = dict(metrics)
            trial.status = "done"
        except EarlyStopped:
            if trial.history:
                trial.metrics = {objective_key: trial.history[-1][1]}
            trial.status = "early_stopped"
        trial.duration_s = time.perf_counter() - t0
        best = exp.best_trial()
        if goal_value is not None and best is not None:
            b = exp.objective(best)
            if (goal == "minimize" and b <= goal_value) or \
               (goal == "maximize" and b >= goal_value):
                break
    exp.save()
    return exp


class EarlyStopped(Exception):
    pass
