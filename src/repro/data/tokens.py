"""Synthetic LM token pipeline: deterministic Markov-ish token streams with
sequence packing and shard-aware batching (the data substrate under
TrainJob for the LM architectures).
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


class TokenStream:
    """Order-1 Markov chain over the vocab with a banded transition kernel:
    cheap, deterministic, non-uniform (so loss actually decreases)."""

    def __init__(self, vocab_size: int, seed: int = 0, band: int = 32):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        self.band = band

    def sample(self, batch: int, seq: int) -> np.ndarray:
        toks = np.empty((batch, seq), np.int32)
        toks[:, 0] = self.rng.integers(0, self.vocab, batch)
        steps = self.rng.integers(1, self.band, size=(batch, seq - 1))
        jump = self.rng.random((batch, seq - 1)) < 0.05
        rand = self.rng.integers(0, self.vocab, size=(batch, seq - 1))
        for t in range(1, seq):
            nxt = (toks[:, t - 1] + steps[:, t - 1]) % self.vocab
            toks[:, t] = np.where(jump[:, t - 1], rand[:, t - 1], nxt)
        return toks


def lm_batches(cfg, batch: int, seq: int, *, seed: int = 0,
               n_batches: Optional[int] = None) -> Iterator[dict]:
    """Batches shaped for models.lm.forward (tokens, labels, + frontend
    stubs for vlm/audio archs)."""
    stream = TokenStream(cfg.vocab_size, seed=seed)
    rng = np.random.default_rng(seed + 1)
    i = 0
    while n_batches is None or i < n_batches:
        toks = stream.sample(batch, seq)
        out = {"tokens": toks, "labels": toks.copy()}
        if cfg.use_mrope:
            pos = np.broadcast_to(np.arange(seq, dtype=np.int32)[None, None],
                                  (batch, 3, seq)).copy()
            out["mrope_positions"] = pos
        if cfg.family == "vlm":
            nv = min(cfg.n_vision_tokens, seq)
            out["vision_embeds"] = rng.normal(
                0, 0.02, (batch, nv, cfg.d_model)).astype(np.float32)
        if cfg.family == "audio":
            out["frames"] = rng.normal(
                0, 1.0, (batch, cfg.encoder_len, cfg.d_model)).astype(np.float32)
        yield out
        i += 1
