"""Synthetic procedural MNIST (offline container: no downloads).

Digits are rendered as anti-aliased seven-segment glyphs on a 28x28 grid
with random translation, scale jitter and pixel noise -- linearly separable
enough that LeNet reaches high accuracy in a few hundred steps, noisy
enough that hyperparameters matter (Katib has something to tune).
Deterministic given the seed.
"""
from __future__ import annotations

import numpy as np

# seven-segment layout:  segments (a top, b tr, c br, d bottom, e bl, f tl, g mid)
_SEGMENTS = {
    "a": ((4, 6), (4, 22)), "b": ((4, 22), (14, 22)), "c": ((14, 22), (24, 22)),
    "d": ((24, 6), (24, 22)), "e": ((14, 6), (24, 6)), "f": ((4, 6), (14, 6)),
    "g": ((14, 6), (14, 22)),
}
_DIGIT_SEGMENTS = {
    0: "abcdef", 1: "bc", 2: "abged", 3: "abgcd", 4: "fgbc",
    5: "afgcd", 6: "afgedc", 7: "abc", 8: "abcdefg", 9: "abcdfg",
}


def _draw_segment(img: np.ndarray, p0, p1, thickness: float):
    (r0, c0), (r1, c1) = p0, p1
    n = 24
    rr = np.linspace(r0, r1, n)
    cc = np.linspace(c0, c1, n)
    ys, xs = np.mgrid[0:28, 0:28]
    for r, c in zip(rr, cc):
        d2 = (ys - r) ** 2 + (xs - c) ** 2
        img += np.exp(-d2 / (2 * thickness ** 2))


def render_digit(digit: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    thick = rng.uniform(0.9, 1.6)
    dr, dc = rng.integers(-2, 3), rng.integers(-2, 3)
    scale = rng.uniform(0.85, 1.1)
    for seg in _DIGIT_SEGMENTS[digit]:
        (r0, c0), (r1, c1) = _SEGMENTS[seg]
        tr = lambda r, c: (14 + (r - 14) * scale + dr, 14 + (c - 14) * scale + dc)
        _draw_segment(img, tr(r0, c0), tr(r1, c1), thick)
    img = np.clip(img, 0, 1)
    img += rng.normal(0, 0.08, img.shape).astype(np.float32)
    return np.clip(img, 0, 1)


def make_dataset(n: int, seed: int = 0):
    """Returns (images (N,28,28,1) f32, labels (N,) i32)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    images = np.stack([render_digit(int(d), rng) for d in labels])
    return images[..., None].astype(np.float32), labels


class Batches:
    """Shuffled epoch iterator with host-side prefetch semantics."""

    def __init__(self, images, labels, batch_size: int, seed: int = 0,
                 drop_remainder: bool = True):
        self.images, self.labels = images, labels
        self.bs = batch_size
        self.rng = np.random.default_rng(seed)
        self.drop = drop_remainder

    def __iter__(self):
        idx = self.rng.permutation(len(self.labels))
        stop = len(idx) - (len(idx) % self.bs if self.drop else 0)
        for i in range(0, stop, self.bs):
            j = idx[i:i + self.bs]
            yield {"image": self.images[j], "label": self.labels[j]}
