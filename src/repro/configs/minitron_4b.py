"""minitron-4b [dense] -- 32L d3072 24H(kv8) ff9216 v256000; pruned nemotron
(squared-ReLU MLP) [arXiv:2407.14679]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minitron-4b", family="dense", citation="arXiv:2407.14679",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216,
        vocab_size=256000, mlp_act="squared_relu",
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, head_dim=0,
        vocab_size=512, d_ff=256, dtype="float32")
