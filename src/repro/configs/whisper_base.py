"""whisper-base [audio] -- 6L enc + 6L dec, d512 8H(kv8) ff2048 v51865;
enc-dec with conv/mel frontend STUBBED (input_specs provides (B,1500,512)
frame embeddings) [arXiv:2212.04356].  Sinusoidal positions, GELU MLP.
Decoder design range is 448 tokens; decode_32k is lowered mechanically
(sharding proof), long_500k skipped (DESIGN.md)."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base", family="audio", citation="arXiv:2212.04356",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab_size=51865, encoder_layers=6, encoder_len=1500,
        mlp_act="gelu", use_rope=False,
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=0,
        vocab_size=512, d_ff=128, encoder_layers=2, encoder_len=30,
        dtype="float32")
