"""h2o-danube-3-4b [dense] -- 24L d3840 32H(kv8) ff10240 v32000;
llama+mistral mix with sliding-window attention (window 4096)
[arXiv:2401.16818]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b", family="dense", citation="arXiv:2401.16818",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
        vocab_size=32000, block_pattern=("local",), sliding_window=4096,
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=0,
        vocab_size=512, d_ff=256, sliding_window=16, dtype="float32")
