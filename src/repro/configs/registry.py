"""Config registry: --arch <id> resolution + the 4 assigned input shapes."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable

from .base import ArchConfig

ARCH_IDS = (
    "granite_moe_3b_a800m",
    "xlstm_1_3b",
    "granite_3_8b",
    "gemma3_4b",
    "deepseek_v2_lite_16b",
    "h2o_danube_3_4b",
    "whisper_base",
    "minitron_4b",
    "qwen2_vl_7b",
    "zamba2_1_2b",
)

# public --arch ids (dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def _normalize(arch: str) -> str:
    """Accept module names, --arch ids, and display names (dots/dashes)."""
    name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {list(ARCH_IDS)}")
    return name


def get_config(arch: str) -> ArchConfig:
    return importlib.import_module(f"repro.configs.{_normalize(arch)}").config()


def get_smoke_config(arch: str) -> ArchConfig:
    return importlib.import_module(f"repro.configs.{_normalize(arch)}").smoke()


def list_archs():
    return list(ARCH_IDS)


def runnable(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """Is (arch, shape) in the dry-run matrix?  DESIGN.md §long_500k."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: no sub-quadratic/bounded-cache "
                       "decode mode (DESIGN.md skip)")
    return True, ""
