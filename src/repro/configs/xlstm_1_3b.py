"""xlstm-1.3b [ssm] -- 48L d2048 4H(kv4) no-FFN v50304; sLSTM + mLSTM blocks
(every 8th layer sLSTM) [arXiv:2405.04517]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm-1.3b", family="ssm", citation="arXiv:2405.04517",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=50304, slstm_every=8, ssm_chunk=256,
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, head_dim=0,
        vocab_size=512, slstm_every=2, ssm_chunk=16, dtype="float32")
