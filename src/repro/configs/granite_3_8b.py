"""granite-3-8b [dense] -- 40L d4096 32H(kv8) ff12800 v49155, GQA
[hf:ibm-granite/granite-3.0-8b-base; assignment bracket cites the 2b card]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b", family="dense", citation="hf:ibm-granite/granite-3.0-8b-base",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12800,
        vocab_size=49155, block_pattern=("global",),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=0,
        vocab_size=512, d_ff=256, dtype="float32")
