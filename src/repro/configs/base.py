"""Architecture config schema shared by all 10 assigned archs + paper's LeNet.

One dataclass covers every family (dense / moe / ssm / hybrid / audio / vlm);
family-specific fields default to "off".  Each ``src/repro/configs/<id>.py``
instantiates the exact assigned spec and a ``smoke()`` reduced variant
(<=2 layers, d_model<=512, <=4 experts) for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    citation: str = ""

    # block pattern ----------------------------------------------------------
    # sequence of block kinds tiled over depth; e.g. gemma3 ("local",)*5+("global",)
    block_pattern: Tuple[str, ...] = ("global",)
    sliding_window: int = 4096       # window for "local"/SWA blocks
    mlp_act: str = "swiglu"          # swiglu | gelu | squared_relu
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True            # whisper: additive sinusoid instead
    scale_embed: bool = False        # gemma: x *= sqrt(d_model)
    norm_eps: float = 1e-6

    # MoE ----------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden (d_ff used for dense layers)
    first_layer_dense: bool = False  # deepseek: layer 0 is a dense MLP
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    expert_pad_to: int = 0           # pad expert count (dead, never-routed
                                     # experts) so E divides the mesh model
                                     # axis -> expert-parallel dispatch
                                     # (perf variant; function unchanged)

    # MLA (deepseek) -------------------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # SSM / xLSTM / Mamba2 ---------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner_mult: int = 2            # d_inner = mult * d_model
    ssm_chunk: int = 256             # chunkwise-scan chunk length
    slstm_every: int = 0             # xlstm: every Nth layer is sLSTM
    shared_attn_every: int = 0       # zamba2: shared attention after every N ssm blocks

    # enc-dec (whisper) --------------------------------------------------------
    encoder_layers: int = 0
    encoder_len: int = 1500          # precomputed frame-embedding length (stub frontend)

    # VLM (qwen2-vl) -----------------------------------------------------------
    use_mrope: bool = False
    n_vision_tokens: int = 256       # precomputed patch embeddings per sample (stub)

    # numerics / runtime -------------------------------------------------------
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"
    use_kernels: bool = False        # dispatch to pallas interpret kernels
    fused_attention: bool = False    # chunked online-softmax attention (no
                                     # S^2 materialisation; pallas on TPU)
    attn_chunk: int = 1024           # kv-chunk for fused attention
    sharding_profile: str = "tp"     # "tp" (model axis active) | "dp" (pure
                                     # data-parallel; batch spans model axis)
    remat: bool = False              # activation checkpointing for train_step
    scan_unroll: bool = False        # dry-run: unroll layer/chunk scans so
                                     # XLA cost analysis sees true totals
                                     # (while bodies are otherwise counted once)
    chunk_unroll: Optional[bool] = None  # override for time-chunk scans only
                                     # (None -> follow scan_unroll); the dry-run
                                     # keeps these rolled + analytically corrected
                                     # to bound compile time
    max_decode_len: int = 0          # kv-cache length for serve_step (set by shape)
    zero1: bool = False              # ZeRO-1: shard optimizer state over data axis

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.d_inner_mult * self.d_model

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic / bounded-cache decode available?  True for state
        recurrences (ssm/hybrid) and for archs with sliding-window layers
        (ring caches); full-attention kinds (global, mla) disqualify unless
        windowed layers bound the non-window cache count.  DESIGN.md
        §long_500k: gemma3's few global layers still fit at batch=1, so
        'local' presence wins there."""
        if self.family in ("ssm", "hybrid"):
            return True
        return "local" in self.block_pattern

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has a decode path (whisper is enc-dec)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -- simple analytic param count for roofline MODEL_FLOPS = 6 N D ---------
    def approx_active_params(self) -> int:
        """Active (per-token) non-embedding params, for 6*N_active*D."""
        D, F, L = self.d_model, self.d_ff, self.n_layers
        hd, Hq, Hkv = self.head_dim, self.n_heads, self.n_kv_heads
        if self.use_mla:
            r = self.kv_lora_rank
            attn = D * Hq * (self.qk_nope_dim + self.qk_rope_dim) + D * (r + self.qk_rope_dim) \
                + r * Hq * (self.qk_nope_dim + self.v_head_dim) + Hq * self.v_head_dim * D
        else:
            attn = D * hd * (Hq + 2 * Hkv) + Hq * hd * D
        if self.family == "ssm":          # xlstm-style block, no separate FFN
            inner = self.d_inner
            per_layer = 2 * D * inner + inner * D  # in/out proj + gates (approx)
            return L * per_layer
        if self.n_experts:
            moe = 3 * D * self.moe_d_ff * (self.top_k + self.n_shared_experts)
            dense_l = 1 if self.first_layer_dense else 0
            return (L - dense_l) * (attn + moe) + dense_l * (attn + 3 * D * F)
        if self.family == "hybrid":
            inner = self.d_inner
            ssm_per = 2 * D * inner + inner * self.ssm_state
            n_attn = L // max(self.shared_attn_every, 1)
            return L * ssm_per + n_attn * (attn + 3 * D * F)
        mlp = (3 if self.mlp_act == "swiglu" else 2) * D * F
        enc = self.encoder_layers * (attn + mlp)
        return L * (attn + mlp) + enc
