"""qwen2-vl-7b [vlm] -- 28L d3584 28H(kv4) ff18944 v152064; M-RoPE (t/h/w
position streams), dynamic-resolution ViT STUBBED (input_specs provides
precomputed patch embeddings + (B,3,S) position ids) [arXiv:2409.12191]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2-vl-7b", family="vlm", citation="arXiv:2409.12191",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
        vocab_size=152064, use_mrope=True, n_vision_tokens=256,
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=0,
        vocab_size=512, d_ff=256, n_vision_tokens=8, dtype="float32")
