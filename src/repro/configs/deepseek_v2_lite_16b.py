"""deepseek-v2-lite-16b [moe] -- 27L d2048 16H(kv16) expert-ff1408 v102400;
MLA (kv_lora 512, decoupled rope 64/nope 128/v 128), 64 routed experts top-6
+ 2 shared, first layer dense [arXiv:2405.04434.  Assignment header says
"64e top-6"; its bracket note "160 routed" describes full V2 -- we build the
actual Lite config per the header, recorded in DESIGN.md]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b", family="moe", citation="arXiv:2405.04434",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
        vocab_size=102400, block_pattern=("mla",),
        n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
        first_layer_dense=True,
        use_mla=True, kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
        v_head_dim=128,
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=0,
        vocab_size=512, d_ff=256, n_experts=4, top_k=2, n_shared_experts=1,
        moe_d_ff=64, kv_lora_rank=32, qk_rope_dim=16, qk_nope_dim=32,
        v_head_dim=32, dtype="float32")
