"""granite-moe-3b-a800m [moe] -- 32L d1536 24H(kv8) expert-ff512 v49155,
40 experts top-8 [hf:ibm-granite/granite-3.0-3b-a800m-base; assignment sheet
header says 40e, bracket cites the 1b-a400m card (32e) -- we follow the 40e
header, discrepancy recorded in DESIGN.md]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m", family="moe", citation="hf:ibm-granite/granite-3.0-3b-a800m-base",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
        vocab_size=49155, n_experts=40, top_k=8, moe_d_ff=512,
        block_pattern=("global",),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, head_dim=0,
        vocab_size=512, n_experts=4, top_k=2, moe_d_ff=64, d_ff=64,
        dtype="float32")
