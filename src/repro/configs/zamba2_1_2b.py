"""zamba2-1.2b [hybrid] -- 38L d2048 32H(kv32) ff8192 v32000 ssm_state=64;
Mamba2 backbone + weight-tied shared attention+MLP block applied every 6
mamba layers [arXiv:2411.15242].  long_500k adaptation: the shared block is
windowed at sliding_window for >64k decode budgets (DESIGN.md deviation)."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b", family="hybrid", citation="arXiv:2411.15242",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab_size=32000, ssm_state=64, shared_attn_every=6,
        d_inner_mult=2, sliding_window=4096, ssm_chunk=256,
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=0,
        vocab_size=512, d_ff=256, ssm_state=16, shared_attn_every=2,
        ssm_chunk=16, sliding_window=16, dtype="float32")
