"""gemma3-4b [dense] -- 34L d2560 8H(kv4) ff10240 v262144; 5:1 local:global
sliding-window pattern (window 1024), 128k context, tied embeddings
[hf:google/gemma-3-4b-pt; assignment bracket cites the 1b card]."""
from .base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-4b", family="dense", citation="hf:google/gemma-3-4b-pt",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
        vocab_size=262144,
        block_pattern=("local", "local", "local", "local", "local", "global"),
        sliding_window=1024, tie_embeddings=True, scale_embed=True,
        mlp_act="swiglu", rope_theta=1_000_000.0,
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=0,
        vocab_size=512, d_ff=256, sliding_window=16,
        block_pattern=("local", "global"), dtype="float32")
